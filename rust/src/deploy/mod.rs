//! Post-Pruning Optimizer deployment formats (PC component 10: "convert
//! the model weights into different inference formats") — both the
//! on-disk side of the paper's size story and, since the storage-backend
//! refactor, the *runtime* formats the engine executes directly:
//!
//!   * DenseF32 — the mutable working format the pruners operate on;
//!   * DenseF16 — half-precision storage (Table II measures fp16 sizes);
//!   * SparseCsr — compressed rows for unstructured-pruned projections
//!     (f16 values, or i8 + grouped scales: "csr8");
//!   * DenseI8 / GroupedI4 — quantized dense with per-(row-group,
//!     column) f32 scales, so pruning masks and low-bit storage stack.
//!
//! `choose_encoding*` runs one pass over the cost table — an ordered
//! list of (eligibility, exact byte formula) rules — and picks the
//! cheapest eligible encoding; a [`QuantSpec`] (from `--quant
//! i8[:group]|i4:group`) unlocks the quantized rows. `ModelWeights::
//! compact[_q]` applies that choice in memory
//! ([`crate::tensor::ProjStorage`]), and [`load_encoded`] reconstructs
//! storage straight from the encoded bytes — no densify round-trip on
//! either path. Deployment files are header-v3 (v3 adds the quantized
//! blob layouts; v2 files load unchanged). See ARCHITECTURE.md §Storage
//! backends.

pub use crate::util::f16;

use anyhow::{Context, Result};

use crate::model::config::{ModelConfig, Proj};
use crate::model::{LayerWeights, ModelWeights};
use crate::tensor::{CsrVals, ProjStorage, Tensor};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    DenseF32,
    DenseF16,
    SparseCsr,
    /// 8-bit dense with per-(row-group, column) f32 scales.
    DenseI8,
    /// Packed 4-bit dense with per-(row-group, column) f32 scales.
    GroupedI4,
    /// CSR pattern with i8 values + grouped scales (pruned+quantized).
    SparseCsrI8,
}

impl Encoding {
    pub fn name(&self) -> &'static str {
        match self {
            Encoding::DenseF32 => "f32",
            Encoding::DenseF16 => "f16",
            Encoding::SparseCsr => "csr",
            Encoding::DenseI8 => "i8",
            Encoding::GroupedI4 => "i4",
            Encoding::SparseCsrI8 => "csr8",
        }
    }

    pub fn from_name(s: &str) -> Result<Encoding> {
        Ok(match s {
            "f32" => Encoding::DenseF32,
            "f16" => Encoding::DenseF16,
            "csr" => Encoding::SparseCsr,
            "i8" => Encoding::DenseI8,
            "i4" => Encoding::GroupedI4,
            "csr8" => Encoding::SparseCsrI8,
            other => anyhow::bail!("unknown encoding '{other}'"),
        })
    }
}

/// Quantization request: bit width (8 or 4) and rows-per-scale-group.
/// This is what `--quant i8[:group]|i4:group` parses into and what the
/// seal/choose machinery threads through to the storage layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantSpec {
    pub bits: u32,
    pub group: usize,
}

impl QuantSpec {
    pub const DEFAULT_GROUP: usize = 128;

    pub fn i8(group: usize) -> QuantSpec {
        QuantSpec { bits: 8, group }
    }

    pub fn i4(group: usize) -> QuantSpec {
        QuantSpec { bits: 4, group }
    }

    /// Largest code on the symmetric grid (127 for i8, 7 for i4).
    pub fn qmax(&self) -> i32 {
        (1 << (self.bits - 1)) - 1
    }

    pub fn label(&self) -> String {
        format!("i{}:{}", self.bits, self.group)
    }

    /// Bytes of the f32 scale grid for a rows × cols projection.
    pub fn scale_bytes(&self, rows: usize, cols: usize) -> usize {
        4 * rows.div_ceil(self.group) * cols
    }

    /// Parse a CLI spec: `i8`, `i8:64`, `i4:128`, … (group defaults to
    /// [`Self::DEFAULT_GROUP`]).
    pub fn parse(s: &str) -> Result<QuantSpec> {
        let (prec, group) = match s.split_once(':') {
            Some((p, g)) => (
                p,
                g.parse::<usize>().ok().with_context(|| {
                    format!(
                        "bad quant group in '{s}' (want i8[:group] or \
                         i4[:group])"
                    )
                })?,
            ),
            None => (s, Self::DEFAULT_GROUP),
        };
        anyhow::ensure!(
            (1..=65536).contains(&group),
            "quant group {group} out of range [1, 65536]"
        );
        match prec {
            "i8" => Ok(QuantSpec::i8(group)),
            "i4" => Ok(QuantSpec::i4(group)),
            other => anyhow::bail!(
                "unknown quant precision '{other}' (want i8 or i4)"
            ),
        }
    }
}

/// Pre-computed projection dimensions the cost model prices from, so
/// sizing loops never rescan a tensor per candidate encoding.
#[derive(Debug, Clone, Copy)]
pub struct ProjDims {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
}

impl ProjDims {
    /// One zero-count scan.
    pub fn of(t: &Tensor) -> ProjDims {
        let rows = t.rows();
        ProjDims {
            rows,
            cols: if rows > 0 { t.numel() / rows } else { 0 },
            nnz: t.numel() - t.zero_count(),
        }
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }
}

/// Serialized quantized blobs lead with a u32 group-size header so
/// `decode_storage` needs no side-channel metadata.
const GROUP_HEADER: usize = 4;

/// One row of the cost model: when may this encoding be picked
/// automatically, and exactly how many bytes does it serialize to
/// (`bytes` must equal the `encode`d blob length — the randomized
/// byte-roundtrip test holds every row to that).
struct EncodingRule {
    e: Encoding,
    eligible: fn(&ProjDims, Option<QuantSpec>) -> bool,
    bytes: fn(&ProjDims, Option<QuantSpec>) -> usize,
}

/// The table behind `choose_encoding*` / `encoded_bytes*`, in priority
/// order: among equal byte counts the earlier row wins (so CSR must
/// *strictly* beat f16 to be chosen, as before). DenseF32 is never
/// chosen automatically — it is the mutable working format, priced here
/// only so explicit sizing questions have one answer. Quantized rows
/// are eligible only when a [`QuantSpec`] with the matching bit width
/// is in play; CSR rows additionally need u16-addressable columns.
const COST_TABLE: [EncodingRule; 6] = [
    EncodingRule {
        e: Encoding::DenseF32,
        eligible: |_, _| false,
        bytes: |d, _| 4 * d.numel(),
    },
    EncodingRule {
        e: Encoding::DenseF16,
        eligible: |_, _| true,
        bytes: |d, _| 2 * d.numel(),
    },
    EncodingRule {
        e: Encoding::SparseCsr,
        eligible: |d, _| d.cols <= 1 << 16,
        // row pointers (u32) + column indices (u16) + f16 values
        bytes: |d, _| 4 * (d.rows + 1) + 2 * d.nnz + 2 * d.nnz,
    },
    EncodingRule {
        e: Encoding::DenseI8,
        eligible: |_, q| matches!(q, Some(q) if q.bits == 8),
        bytes: |d, q| {
            let q = q.expect("i8 sizing needs a QuantSpec");
            GROUP_HEADER + q.scale_bytes(d.rows, d.cols) + d.numel()
        },
    },
    EncodingRule {
        e: Encoding::GroupedI4,
        eligible: |_, q| matches!(q, Some(q) if q.bits == 4),
        bytes: |d, q| {
            let q = q.expect("i4 sizing needs a QuantSpec");
            GROUP_HEADER
                + q.scale_bytes(d.rows, d.cols)
                + d.rows * d.cols.div_ceil(2)
        },
    },
    EncodingRule {
        e: Encoding::SparseCsrI8,
        eligible: |d, q| {
            matches!(q, Some(q) if q.bits == 8) && d.cols <= 1 << 16
        },
        // csr8 stores the full pruning mask (entries that quantize to
        // code 0 stay explicit), so nnz here is exact, not a bound
        bytes: |d, q| {
            let q = q.expect("csr8 sizing needs a QuantSpec");
            4 * (d.rows + 1)
                + 2 * d.nnz
                + GROUP_HEADER
                + q.scale_bytes(d.rows, d.cols)
                + d.nnz
        },
    },
];

fn rule(e: Encoding) -> &'static EncodingRule {
    COST_TABLE.iter().find(|r| r.e == e).expect("encoding in table")
}

/// Serialized size (bytes) under an encoding, from pre-computed
/// dimensions. Quantized encodings need `quant` (panics otherwise —
/// group size determines the scale grid).
pub fn encoded_bytes_dims(
    d: &ProjDims,
    e: Encoding,
    quant: Option<QuantSpec>,
) -> usize {
    (rule(e).bytes)(d, quant)
}

/// Legacy dimension-tuple sizing (f32/f16/csr only; quantized encodings
/// panic — they need a [`QuantSpec`], use [`encoded_bytes_dims`]).
pub fn encoded_bytes_for(
    rows: usize,
    numel: usize,
    nnz: usize,
    e: Encoding,
) -> usize {
    let cols = if rows > 0 { numel / rows } else { 0 };
    encoded_bytes_dims(&ProjDims { rows, cols, nnz }, e, None)
}

/// Serialized size (bytes) of one tensor under an encoding (one scan).
pub fn encoded_bytes_q(
    t: &Tensor,
    e: Encoding,
    quant: Option<QuantSpec>,
) -> usize {
    encoded_bytes_dims(&ProjDims::of(t), e, quant)
}

/// [`encoded_bytes_q`] without a quant spec (f32/f16/csr).
pub fn encoded_bytes(t: &Tensor, e: Encoding) -> usize {
    encoded_bytes_q(t, e, None)
}

/// Pick the cheapest eligible encoding from pre-computed dimensions —
/// one pass over the cost table; earlier rows win ties.
pub fn choose_encoding_dims(
    d: &ProjDims,
    quant: Option<QuantSpec>,
) -> Encoding {
    let mut best: Option<(usize, Encoding)> = None;
    for r in COST_TABLE.iter() {
        if !(r.eligible)(d, quant) {
            continue;
        }
        let b = (r.bytes)(d, quant);
        if best.map_or(true, |(bb, _)| b < bb) {
            best = Some((b, r.e));
        }
    }
    // DenseF16 is always eligible, so `best` is always set.
    best.expect("cost table has an eligible row").1
}

/// Pick the cheapest encoding from pre-computed dimensions (no quant).
pub fn choose_encoding_for(rows: usize, numel: usize, nnz: usize) -> Encoding {
    let cols = if rows > 0 { numel / rows } else { 0 };
    choose_encoding_dims(&ProjDims { rows, cols, nnz }, None)
}

/// Pick the cheapest encoding for a tensor under an optional quant spec
/// (single zero-count scan).
pub fn choose_encoding_q(t: &Tensor, quant: Option<QuantSpec>) -> Encoding {
    choose_encoding_dims(&ProjDims::of(t), quant)
}

/// Pick the cheapest encoding for a tensor (no quantization in play).
pub fn choose_encoding(t: &Tensor) -> Encoding {
    choose_encoding_q(t, None)
}

/// Seal a dense tensor into runtime storage under an explicit encoding;
/// quantized encodings take their group size from `quant` (panics when
/// absent).
pub fn seal_q(
    t: &Tensor,
    e: Encoding,
    quant: Option<QuantSpec>,
) -> ProjStorage {
    let group = |what: &str| {
        quant
            .unwrap_or_else(|| panic!("{what} sealing needs a QuantSpec"))
            .group
    };
    match e {
        Encoding::DenseF32 => ProjStorage::from_dense(t.clone()),
        Encoding::DenseF16 => ProjStorage::seal_f16(t),
        Encoding::SparseCsr => ProjStorage::seal_csr(t),
        Encoding::DenseI8 => ProjStorage::seal_i8(t, group("i8")),
        Encoding::GroupedI4 => ProjStorage::seal_i4(t, group("i4")),
        Encoding::SparseCsrI8 => ProjStorage::seal_csr_i8(t, group("csr8")),
    }
}

/// Seal under an explicit encoding (f32/f16/csr).
pub fn seal(t: &Tensor, e: Encoding) -> ProjStorage {
    seal_q(t, e, None)
}

/// Seal under the cheapest encoding the optional quant spec makes
/// eligible. `ModelWeights::compact[_q]` and the streaming pipeline's
/// per-layer seal both go through this, so a layer sealed mid-pipeline
/// is bit-identical to one compacted at the end of a sequential pass.
pub fn seal_auto_q(t: &Tensor, quant: Option<QuantSpec>) -> ProjStorage {
    seal_q(t, choose_encoding_q(t, quant), quant)
}

/// [`seal_auto_q`] with no quantization: cheapest of f16/CSR.
pub fn seal_auto(t: &Tensor) -> ProjStorage {
    seal_auto_q(t, None)
}

/// Append a quantized value section: `[u32 group][f32 scales…][payload]`.
fn push_quant_section(
    out: &mut Vec<u8>,
    group: usize,
    scales: &[f32],
    payload: &[u8],
) {
    out.extend_from_slice(&(group as u32).to_le_bytes());
    for s in scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out.extend_from_slice(payload);
}

/// Serialize runtime storage in its own encoding — sealed backends
/// stream their buffers out directly (no densify round-trip); a dense
/// f32 working copy gets `choose_encoding` applied first.
pub fn encode_storage(s: &ProjStorage) -> (Encoding, Vec<u8>) {
    match s {
        ProjStorage::DenseF32(t) => {
            let e = choose_encoding(t);
            (e, encode(t, e))
        }
        ProjStorage::DenseF16 { bits, .. } => {
            let mut out = Vec::with_capacity(2 * bits.len());
            for b in bits {
                out.extend_from_slice(&b.to_le_bytes());
            }
            (Encoding::DenseF16, out)
        }
        ProjStorage::DenseI8 { vals, scales, group, .. } => {
            let mut out = Vec::with_capacity(
                GROUP_HEADER + 4 * scales.len() + vals.len(),
            );
            let payload: Vec<u8> = vals.iter().map(|&v| v as u8).collect();
            push_quant_section(&mut out, *group, scales, &payload);
            (Encoding::DenseI8, out)
        }
        ProjStorage::GroupedI4 { packed, scales, group, .. } => {
            let mut out = Vec::with_capacity(
                GROUP_HEADER + 4 * scales.len() + packed.len(),
            );
            push_quant_section(&mut out, *group, scales, packed);
            (Encoding::GroupedI4, out)
        }
        ProjStorage::SparseCsr { row_ptr, col_idx, vals, .. } => {
            let mut out = Vec::with_capacity(
                4 * row_ptr.len() + 2 * col_idx.len(),
            );
            for p in row_ptr {
                out.extend_from_slice(&p.to_le_bytes());
            }
            for c in col_idx {
                out.extend_from_slice(&c.to_le_bytes());
            }
            match vals {
                CsrVals::F16(vals_f16) => {
                    for v in vals_f16 {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                    (Encoding::SparseCsr, out)
                }
                CsrVals::I8 { vals, scales, group } => {
                    let payload: Vec<u8> =
                        vals.iter().map(|&v| v as u8).collect();
                    push_quant_section(&mut out, *group, scales, &payload);
                    (Encoding::SparseCsrI8, out)
                }
            }
        }
    }
}

/// Encode a tensor; `decode` inverts (f16 rounding — and quantization —
/// are lossy by design). Quantized encodings need [`encode_q`].
pub fn encode(t: &Tensor, e: Encoding) -> Vec<u8> {
    match e {
        Encoding::DenseF32 => {
            let mut out = Vec::with_capacity(4 * t.numel());
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
        Encoding::DenseF16 => {
            let mut out = Vec::with_capacity(2 * t.numel());
            for &v in &t.data {
                out.extend_from_slice(&f16::to_bits(v).to_le_bytes());
            }
            out
        }
        Encoding::SparseCsr => encode_storage(&ProjStorage::seal_csr(t)).1,
        Encoding::DenseI8 | Encoding::GroupedI4 | Encoding::SparseCsrI8 => {
            panic!(
                "quantized encoding {} needs a QuantSpec — use encode_q",
                e.name()
            )
        }
    }
}

/// Encode a tensor under a quantized encoding (seal + stream).
pub fn encode_q(t: &Tensor, e: Encoding, quant: QuantSpec) -> Vec<u8> {
    encode_storage(&seal_q(t, e, Some(quant))).1
}

/// Parse a serialized CSR index: row pointers (validated monotone,
/// starting at 0) and column indices (validated in range). Returns the
/// index plus the offset where the value payload begins.
fn parse_csr_index(
    bytes: &[u8],
    r: usize,
    c: usize,
) -> Result<(Vec<u32>, Vec<u16>, usize, usize)> {
    let ptr_bytes = 4 * (r + 1);
    anyhow::ensure!(bytes.len() >= ptr_bytes, "csr header");
    let mut row_ptr = Vec::with_capacity(r + 1);
    for ch in bytes[..ptr_bytes].chunks_exact(4) {
        row_ptr.push(u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
    }
    anyhow::ensure!(
        row_ptr.first() == Some(&0),
        "csr row_ptr must start at 0"
    );
    for w in row_ptr.windows(2) {
        anyhow::ensure!(w[0] <= w[1], "csr row_ptr not monotone");
    }
    let nnz = *row_ptr.last().unwrap() as usize;
    let vals_off = ptr_bytes + 2 * nnz;
    anyhow::ensure!(bytes.len() >= vals_off, "csr columns truncated");
    let col_idx: Vec<u16> = bytes[ptr_bytes..vals_off]
        .chunks_exact(2)
        .map(|ch| u16::from_le_bytes([ch[0], ch[1]]))
        .collect();
    for &j in &col_idx {
        anyhow::ensure!((j as usize) < c, "csr col oob");
    }
    Ok((row_ptr, col_idx, nnz, vals_off))
}

/// Parse a quantized value section `[u32 group][f32 scales…][payload]`
/// for an `r × c` projection whose payload is `payload_len` bytes.
fn parse_quant_section(
    bytes: &[u8],
    r: usize,
    c: usize,
    payload_len: usize,
    what: &str,
) -> Result<(usize, Vec<f32>, Vec<u8>)> {
    anyhow::ensure!(bytes.len() >= GROUP_HEADER, "{what} group header");
    let group =
        u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    anyhow::ensure!(group >= 1, "{what} group must be >= 1");
    let sb = 4 * r.div_ceil(group) * c;
    anyhow::ensure!(
        bytes.len() == GROUP_HEADER + sb + payload_len,
        "{what} payload size"
    );
    let scales: Vec<f32> = bytes[GROUP_HEADER..GROUP_HEADER + sb]
        .chunks_exact(4)
        .map(|ch| f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]))
        .collect();
    Ok((group, scales, bytes[GROUP_HEADER + sb..].to_vec()))
}

/// Parse encoded bytes straight into runtime storage (2-D tensors only;
/// this is what `load_encoded` uses so a shipped CSR/f16/quantized
/// projection never materializes as dense f32).
pub fn decode_storage(
    bytes: &[u8],
    shape: &[usize],
    e: Encoding,
) -> Result<ProjStorage> {
    anyhow::ensure!(shape.len() == 2, "projection storage is 2-D");
    let (r, c) = (shape[0], shape[1]);
    match e {
        Encoding::DenseF32 => Ok(ProjStorage::from_dense(decode(
            bytes, shape, e,
        )?)),
        Encoding::DenseF16 => {
            anyhow::ensure!(bytes.len() == 2 * r * c, "f16 size");
            let bits = bytes
                .chunks_exact(2)
                .map(|ch| u16::from_le_bytes([ch[0], ch[1]]))
                .collect();
            Ok(ProjStorage::DenseF16 { bits, shape: [r, c] })
        }
        Encoding::DenseI8 => {
            let (group, scales, payload) =
                parse_quant_section(bytes, r, c, r * c, "i8")?;
            let vals: Vec<i8> =
                payload.iter().map(|&b| b as i8).collect();
            Ok(ProjStorage::DenseI8 { vals, scales, group, shape: [r, c] })
        }
        Encoding::GroupedI4 => {
            let stride = c.div_ceil(2);
            let (group, scales, packed) =
                parse_quant_section(bytes, r, c, r * stride, "i4")?;
            Ok(ProjStorage::GroupedI4 {
                packed,
                scales,
                group,
                shape: [r, c],
            })
        }
        Encoding::SparseCsr => {
            let (row_ptr, col_idx, nnz, vals_off) =
                parse_csr_index(bytes, r, c)?;
            anyhow::ensure!(
                bytes.len() == vals_off + 2 * nnz,
                "csr payload size"
            );
            let vals_f16: Vec<u16> = bytes[vals_off..]
                .chunks_exact(2)
                .map(|ch| u16::from_le_bytes([ch[0], ch[1]]))
                .collect();
            Ok(ProjStorage::SparseCsr {
                row_ptr,
                col_idx,
                vals: CsrVals::F16(vals_f16),
                shape: [r, c],
                nnz,
            })
        }
        Encoding::SparseCsrI8 => {
            let (row_ptr, col_idx, nnz, vals_off) =
                parse_csr_index(bytes, r, c)?;
            let (group, scales, payload) =
                parse_quant_section(&bytes[vals_off..], r, c, nnz, "csr8")?;
            let vals: Vec<i8> =
                payload.iter().map(|&b| b as i8).collect();
            Ok(ProjStorage::SparseCsr {
                row_ptr,
                col_idx,
                vals: CsrVals::I8 { vals, scales, group },
                shape: [r, c],
                nnz,
            })
        }
    }
}

/// Decode to a dense f32 tensor (norms/embeddings, tests, tooling).
pub fn decode(
    bytes: &[u8],
    shape: &[usize],
    e: Encoding,
) -> Result<Tensor> {
    let numel: usize = shape.iter().product();
    match e {
        Encoding::DenseF32 => {
            anyhow::ensure!(bytes.len() == 4 * numel, "f32 size");
            let mut t = Tensor::zeros(shape);
            for (i, ch) in bytes.chunks_exact(4).enumerate() {
                t.data[i] =
                    f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
            Ok(t)
        }
        Encoding::DenseF16 => {
            anyhow::ensure!(bytes.len() == 2 * numel, "f16 size");
            let mut t = Tensor::zeros(shape);
            for (i, ch) in bytes.chunks_exact(2).enumerate() {
                t.data[i] =
                    f16::from_bits(u16::from_le_bytes([ch[0], ch[1]]));
            }
            Ok(t)
        }
        Encoding::SparseCsr
        | Encoding::DenseI8
        | Encoding::GroupedI4
        | Encoding::SparseCsrI8 => {
            Ok(decode_storage(bytes, shape, e)?.to_dense())
        }
    }
}

/// Bytes one projection contributes to the deployment file: for a
/// still-dense working copy, the cost table's pick; for sealed storage,
/// its resident bytes plus serialization framing (the u32 group header
/// quantized blobs carry on disk but not in memory).
fn storage_shipped_bytes(s: &ProjStorage) -> usize {
    match s {
        ProjStorage::DenseF32(t) => {
            let d = ProjDims::of(t);
            encoded_bytes_dims(&d, choose_encoding_dims(&d, None), None)
        }
        sealed => {
            let framing = match sealed {
                ProjStorage::DenseI8 { .. }
                | ProjStorage::GroupedI4 { .. }
                | ProjStorage::SparseCsr {
                    vals: CsrVals::I8 { .. }, ..
                } => GROUP_HEADER,
                _ => 0,
            };
            sealed.resident_bytes() + framing
        }
    }
}

/// Total shipped size of a model under per-projection `choose_encoding`
/// (embeddings/head ship f16; norms ship exact f32). Sealed projections
/// reuse their cached nnz instead of rescanning.
pub fn shipped_bytes(m: &ModelWeights) -> usize {
    let mut total = 2 * (m.embed.numel() + m.lm_head.numel())
        + 4 * m.final_norm.len();
    for l in &m.layers {
        total += 4 * (l.attn_norm.len() + l.ffn_norm.len());
        for &p in Proj::all().iter() {
            total += storage_shipped_bytes(l.proj(p));
        }
    }
    total
}

struct BlobWriter {
    blobs: Vec<u8>,
    entries: Vec<Json>,
}

impl BlobWriter {
    fn add(&mut self, name: &str, shape: &[usize], e: Encoding, data: &[u8]) {
        let mut o = Json::obj();
        o.set("name", Json::str(name));
        o.set(
            "shape",
            Json::from_f64s(
                &shape.iter().map(|&s| s as f64).collect::<Vec<_>>(),
            ),
        );
        o.set("encoding", Json::str(e.name()));
        o.set("offset", Json::num(self.blobs.len() as f64));
        o.set("bytes", Json::num(data.len() as f64));
        self.blobs.extend_from_slice(data);
        self.entries.push(o);
    }

    fn add_tensor(&mut self, name: &str, t: &Tensor, e: Encoding) {
        let data = encode(t, e);
        self.add(name, &t.shape, e, &data);
    }

    fn add_vec(&mut self, name: &str, v: &[f32]) {
        let t = Tensor::new(v.to_vec(), vec![v.len()]);
        self.add_tensor(name, &t, Encoding::DenseF32);
    }
}

fn usizes_json(v: &[usize]) -> Json {
    Json::from_f64s(&v.iter().map(|&x| x as f64).collect::<Vec<_>>())
}

/// Write the whole model in deployment format (header JSON + blobs).
/// The header carries the config and per-layer kept structure so
/// [`load_encoded`] can rebuild a runnable `ModelWeights` whose
/// projections live directly in their encoded storage backend.
pub fn export_model(m: &ModelWeights, path: &std::path::Path) -> Result<usize> {
    let mut w = BlobWriter { blobs: Vec::new(), entries: Vec::new() };
    w.add_tensor("embed", &m.embed, Encoding::DenseF16);
    for (li, l) in m.layers.iter().enumerate() {
        w.add_vec(&format!("l{li}.attn_norm"), &l.attn_norm);
        w.add_vec(&format!("l{li}.ffn_norm"), &l.ffn_norm);
        for &p in Proj::all().iter() {
            let s = l.proj(p);
            let (e, data) = encode_storage(s);
            let shape = s.shape();
            w.add(&format!("l{li}.{}", p.name()), &shape, e, &data);
        }
    }
    w.add_vec("final_norm", &m.final_norm);
    w.add_tensor("lm_head", &m.lm_head, Encoding::DenseF16);

    let mut header = Json::obj();
    header.set("model", Json::str(&m.cfg.name));
    // v3 adds the quantized encodings (i8/i4/csr8); a file that only
    // uses f32/f16/csr blobs still parses under v2 readers, but we
    // stamp the writer's format generation.
    header.set("version", Json::num(3.0));
    header.set("config", m.cfg.to_json());
    header.set(
        "layers",
        Json::Arr(
            m.layers
                .iter()
                .map(|l| {
                    let mut o = Json::obj();
                    o.set("kept_heads", usizes_json(&l.kept_heads));
                    o.set("kept_channels", usizes_json(&l.kept_channels));
                    o
                })
                .collect(),
        ),
    );
    header.set("tensors", Json::Arr(w.entries));
    let hs = header.to_string();
    let mut file = Vec::new();
    file.extend_from_slice(&(hs.len() as u64).to_le_bytes());
    file.extend_from_slice(hs.as_bytes());
    file.extend_from_slice(&w.blobs);
    std::fs::write(path, &file)?;
    Ok(file.len())
}

type TensorTable =
    std::collections::HashMap<String, (Vec<usize>, Encoding, usize, usize)>;

fn fetch_blob<'a>(
    table: &TensorTable,
    blobs: &'a [u8],
    name: &str,
) -> Result<(Vec<usize>, Encoding, &'a [u8])> {
    let (shape, e, off, len) = table
        .get(name)
        .with_context(|| format!("deploy tensor {name}"))?
        .clone();
    Ok((shape, e, &blobs[off..off + len]))
}

/// Load a deployment file into a runnable `ModelWeights`, constructing
/// each projection's [`ProjStorage`] directly from the encoded bytes —
/// a 70 % CSR projection is never densified to f32 on the way in.
pub fn load_encoded(path: &std::path::Path) -> Result<ModelWeights> {
    let file = std::fs::read(path)?;
    anyhow::ensure!(file.len() >= 8, "deploy file truncated");
    let hlen = u64::from_le_bytes(file[..8].try_into().unwrap()) as usize;
    anyhow::ensure!(file.len() >= 8 + hlen, "deploy header truncated");
    let header = std::str::from_utf8(&file[8..8 + hlen])
        .map_err(|_| anyhow::anyhow!("deploy header not utf8"))?;
    let j = Json::parse(header)
        .map_err(|e| anyhow::anyhow!("deploy header: {e}"))?;
    let version =
        j.get("version").and_then(|v| v.as_usize()).unwrap_or(2);
    anyhow::ensure!(
        (2..=3).contains(&version),
        "deploy file version {version} unsupported (this build reads v2-v3)"
    );
    let cfg = ModelConfig::from_json(
        j.get("config")
            .context("deploy header missing config (v1 file? re-export)")?,
    )?;
    let blobs = &file[8 + hlen..];

    let mut table: TensorTable = TensorTable::new();
    for e in j.get("tensors").and_then(|v| v.as_arr()).context("tensors")? {
        let name = e
            .get("name")
            .and_then(|v| v.as_str())
            .context("tensor name")?;
        let shape: Vec<usize> = e
            .get("shape")
            .and_then(|v| v.as_arr())
            .context("tensor shape")?
            .iter()
            .map(|s| {
                s.as_usize()
                    .with_context(|| format!("tensor shape entry for {name}"))
            })
            .collect::<Result<Vec<usize>>>()?;
        let enc = Encoding::from_name(
            e.get("encoding")
                .and_then(|v| v.as_str())
                .context("tensor encoding")?,
        )?;
        let offset =
            e.get("offset").and_then(|v| v.as_usize()).context("offset")?;
        let nbytes =
            e.get("bytes").and_then(|v| v.as_usize()).context("bytes")?;
        anyhow::ensure!(offset + nbytes <= blobs.len(), "blob out of range");
        table.insert(name.to_string(), (shape, enc, offset, nbytes));
    }
    let dense = |name: &str| -> Result<Tensor> {
        let (shape, e, b) = fetch_blob(&table, blobs, name)?;
        decode(b, &shape, e)
    };

    let layers_meta =
        j.get("layers").and_then(|v| v.as_arr()).context("deploy layers")?;
    anyhow::ensure!(layers_meta.len() == cfg.n_layers, "layer count");
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for (li, lm) in layers_meta.iter().enumerate() {
        let kept = |key: &str| -> Result<Vec<usize>> {
            lm.get(key)
                .and_then(|v| v.as_arr())
                .with_context(|| format!("l{li}.{key}"))?
                .iter()
                .map(|x| {
                    x.as_usize()
                        .with_context(|| format!("l{li}.{key} entry"))
                })
                .collect::<Result<Vec<usize>>>()
        };
        let mut projs: Vec<ProjStorage> = Vec::with_capacity(7);
        for &p in Proj::all().iter() {
            let (shape, e, b) =
                fetch_blob(&table, blobs, &format!("l{li}.{}", p.name()))?;
            projs.push(decode_storage(b, &shape, e)?);
        }
        let projs: [ProjStorage; 7] = projs
            .try_into()
            .map_err(|_| anyhow::anyhow!("projection count"))?;
        layers.push(LayerWeights {
            attn_norm: dense(&format!("l{li}.attn_norm"))?.data,
            ffn_norm: dense(&format!("l{li}.ffn_norm"))?.data,
            projs,
            kept_heads: kept("kept_heads")?,
            kept_channels: kept("kept_channels")?,
        });
    }
    Ok(ModelWeights {
        embed: dense("embed")?,
        lm_head: dense("lm_head")?,
        final_norm: dense("final_norm")?.data,
        cfg,
        layers,
    })
}

/// Read ONLY the header of a deployment file and return its
/// [`ModelConfig`]. This is the cheap metadata probe scale-to-zero
/// registry entries use at registration time (vocab and context for
/// admission validation) — no blob decode, no weight residency; the
/// full [`load_encoded`] runs later, at first wake.
pub fn load_config(path: &std::path::Path) -> Result<ModelConfig> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8).context("deploy file truncated")?;
    let hlen = u64::from_le_bytes(len8) as usize;
    anyhow::ensure!(hlen < 1 << 30, "deploy header length implausible");
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes).context("deploy header truncated")?;
    let header = std::str::from_utf8(&hbytes)
        .map_err(|_| anyhow::anyhow!("deploy header not utf8"))?;
    let j = Json::parse(header)
        .map_err(|e| anyhow::anyhow!("deploy header: {e}"))?;
    ModelConfig::from_json(
        j.get("config")
            .context("deploy header missing config (v1 file? re-export)")?,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::testutil::random_model;
    use crate::util::rng::Pcg32;

    fn rand_t(seed: u64, r: usize, c: usize) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        Tensor::new(
            (0..r * c).map(|_| rng.normal()).collect(),
            vec![r, c],
        )
    }

    #[test]
    fn f32_roundtrip_exact() {
        let t = rand_t(1, 7, 9);
        let b = encode(&t, Encoding::DenseF32);
        let t2 = decode(&b, &t.shape, Encoding::DenseF32).unwrap();
        assert_eq!(t.data, t2.data);
    }

    #[test]
    fn f16_roundtrip_close() {
        let t = rand_t(2, 8, 8);
        let b = encode(&t, Encoding::DenseF16);
        let t2 = decode(&b, &t.shape, Encoding::DenseF16).unwrap();
        for (a, b) in t.data.iter().zip(t2.data.iter()) {
            assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()));
        }
    }

    #[test]
    fn csr_roundtrip_preserves_pattern() {
        let mut t = rand_t(3, 10, 14);
        // zero 70%
        for (i, v) in t.data.iter_mut().enumerate() {
            if i % 10 < 7 {
                *v = 0.0;
            }
        }
        let b = encode(&t, Encoding::SparseCsr);
        let t2 = decode(&b, &t.shape, Encoding::SparseCsr).unwrap();
        for (a, b) in t.data.iter().zip(t2.data.iter()) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            } else {
                assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()));
            }
        }
        assert!(b.len() < encoded_bytes(&t, Encoding::DenseF16));
    }

    #[test]
    fn randomized_sparsity_storage_byte_roundtrip() {
        // every encoding, across random sparsity levels: bytes →
        // decode_storage → re-encode must be stable, and the storage
        // must agree with the dense decode
        let mut rng = Pcg32::seeded(44);
        for trial in 0u64..12 {
            let mut t = rand_t(100 + trial, 9 + trial as usize, 17);
            let sparsity = rng.f64();
            for v in t.data.iter_mut() {
                if rng.f64() < sparsity {
                    *v = 0.0;
                }
            }
            for e in
                [Encoding::DenseF32, Encoding::DenseF16, Encoding::SparseCsr]
            {
                let bytes = encode(&t, e);
                assert_eq!(
                    bytes.len(),
                    encoded_bytes(&t, e),
                    "size formula mismatch for {}",
                    e.name()
                );
                let s = decode_storage(&bytes, &t.shape, e).unwrap();
                let dense = decode(&bytes, &t.shape, e).unwrap();
                assert_eq!(s.to_dense().data, dense.data);
                // re-encode is byte-identical (canonical form)
                let (e2, bytes2) = encode_storage(&s);
                if e != Encoding::DenseF32 {
                    assert_eq!(e2, e);
                    assert_eq!(bytes2, bytes, "trial {trial} {}", e.name());
                }
            }
            // quantized encodings: same byte-exactness contract (note
            // cols=17 is odd, so i4's pad nibble is exercised)
            for (e, q) in [
                (Encoding::DenseI8, QuantSpec::i8(4)),
                (Encoding::GroupedI4, QuantSpec::i4(4)),
                (Encoding::SparseCsrI8, QuantSpec::i8(8)),
            ] {
                let bytes = encode_q(&t, e, q);
                assert_eq!(
                    bytes.len(),
                    encoded_bytes_q(&t, e, Some(q)),
                    "size formula mismatch for {}",
                    e.name()
                );
                let s = decode_storage(&bytes, &t.shape, e).unwrap();
                assert_eq!(
                    s.to_dense().data,
                    decode(&bytes, &t.shape, e).unwrap().data
                );
                let (e2, bytes2) = encode_storage(&s);
                assert_eq!(e2, e);
                assert_eq!(bytes2, bytes, "trial {trial} {}", e.name());
            }
        }
    }

    #[test]
    fn quant_spec_parses_cli_forms() {
        assert_eq!(QuantSpec::parse("i8").unwrap(), QuantSpec::i8(128));
        assert_eq!(QuantSpec::parse("i8:64").unwrap(), QuantSpec::i8(64));
        assert_eq!(QuantSpec::parse("i4:32").unwrap(), QuantSpec::i4(32));
        assert_eq!(QuantSpec::i8(128).qmax(), 127);
        assert_eq!(QuantSpec::i4(128).qmax(), 7);
        assert!(QuantSpec::parse("i2:64").is_err());
        assert!(QuantSpec::parse("i8:0").is_err());
        assert!(QuantSpec::parse("i8:x").is_err());
    }

    #[test]
    fn cost_table_picks_quantized_rows_only_under_spec() {
        let dense = rand_t(40, 64, 64);
        let mut sparse = dense.clone();
        for (i, v) in sparse.data.iter_mut().enumerate() {
            if i % 10 != 0 {
                *v = 0.0; // 90% zeros
            }
        }
        let i8s = Some(QuantSpec::i8(64));
        let i4s = Some(QuantSpec::i4(64));
        // no spec: unchanged legacy behavior
        assert_eq!(choose_encoding_q(&dense, None), Encoding::DenseF16);
        assert_eq!(choose_encoding_q(&sparse, None), Encoding::SparseCsr);
        // i8 spec: dense → i8, heavily pruned → csr8
        assert_eq!(choose_encoding_q(&dense, i8s), Encoding::DenseI8);
        assert_eq!(choose_encoding_q(&sparse, i8s), Encoding::SparseCsrI8);
        // i4 spec: packed nibbles beat everything dense; i8 rows are
        // ineligible at 4 bits
        assert_eq!(choose_encoding_q(&dense, i4s), Encoding::GroupedI4);
        // wide projections fall back to dense rows: u16 column indices
        // can't address cols > 65536
        let wide = ProjDims { rows: 512, cols: (1 << 16) + 1, nnz: 1000 };
        assert_eq!(choose_encoding_dims(&wide, None), Encoding::DenseF16);
        assert_eq!(
            choose_encoding_dims(&wide, Some(QuantSpec::i8(128))),
            Encoding::DenseI8
        );
    }

    #[test]
    fn export_stamps_v3_and_rejects_unknown_versions() {
        let m = random_model(406);
        let path = std::env::temp_dir().join("mosaic_version_gate.bin");
        export_model(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let hlen =
            u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
        let header = std::str::from_utf8(&bytes[8..8 + hlen]).unwrap();
        assert!(header.contains("\"version\":3"), "writer stamps v3");
        // same-length header patch keeps the length prefix valid
        let patch = |from: &str, to: &str| {
            assert_eq!(from.len(), to.len());
            let mut b = bytes.clone();
            let h = header.replace(from, to);
            b[8..8 + hlen].copy_from_slice(h.as_bytes());
            std::fs::write(&path, &b).unwrap();
        };
        // v2 artifacts (pre-quant format) still load
        patch("\"version\":3", "\"version\":2");
        assert!(load_encoded(&path).is_ok());
        // a future version is rejected with a clear error, not garbage
        patch("\"version\":3", "\"version\":9");
        let err = load_encoded(&path).unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn choose_encoding_crossover() {
        let dense = rand_t(4, 16, 16);
        assert_eq!(choose_encoding(&dense), Encoding::DenseF16);
        let mut sparse = dense.clone();
        for (i, v) in sparse.data.iter_mut().enumerate() {
            if i % 5 != 0 {
                *v = 0.0; // 80% zeros
            }
        }
        assert_eq!(choose_encoding(&sparse), Encoding::SparseCsr);
        // the nnz-parameterized variant agrees with the scanning one
        let nnz = sparse.numel() - sparse.zero_count();
        assert_eq!(
            choose_encoding_for(sparse.rows(), sparse.numel(), nnz),
            Encoding::SparseCsr
        );
    }

    #[test]
    fn shipped_bytes_shrink_with_unstructured_pruning() {
        // the paper: UP doesn't shrink the RESIDENT model (until
        // compact()) — but the deployment FILE should shrink via CSR
        let m = random_model(401);
        let dense_file = shipped_bytes(&m);
        let mut pruned = m.clone();
        for l in pruned.layers.iter_mut() {
            for p in l.projs.iter_mut() {
                let t = p.dense_mut();
                let sc: Vec<f64> =
                    t.data.iter().map(|x| x.abs() as f64).collect();
                crate::prune::unstructured::mask_lowest(t, &sc, 0.8);
            }
        }
        assert_eq!(pruned.model_bytes(), m.model_bytes());
        assert!(
            shipped_bytes(&pruned) < dense_file,
            "CSR file must shrink: {} vs {dense_file}",
            shipped_bytes(&pruned)
        );
        // sealing does not change what would be shipped
        let mut sealed = pruned.clone();
        sealed.compact();
        assert_eq!(shipped_bytes(&sealed), shipped_bytes(&pruned));
    }

    #[test]
    fn export_writes_parseable_file() {
        let m = random_model(402);
        let path = std::env::temp_dir().join("mosaic_export_test.bin");
        let n = export_model(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), n);
        let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap())
            as usize;
        let header = std::str::from_utf8(&bytes[8..8 + hlen]).unwrap();
        let j = crate::util::json::Json::parse(header).unwrap();
        let tensors = j.get("tensors").unwrap().as_arr().unwrap();
        // embed + per-layer (2 norms + 7 projs) + final_norm + lm_head
        assert_eq!(tensors.len(), 1 + m.cfg.n_layers * 9 + 2);
        assert!(j.get("config").is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_config_reads_header_without_blobs() {
        let m = random_model(405);
        let path = std::env::temp_dir().join("mosaic_load_config.bin");
        export_model(&m, &path).unwrap();
        let cfg = load_config(&path).unwrap();
        assert_eq!(cfg.vocab, m.cfg.vocab);
        assert_eq!(cfg.n_layers, m.cfg.n_layers);
        assert_eq!(cfg.ctx, m.cfg.ctx);
        // truncating below the header must fail cleanly, not panic
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..6]).unwrap();
        assert!(load_config(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn export_load_roundtrip_without_densify() {
        use crate::model::engine::forward_full;
        // prune 70% so CSR is chosen, then ship and reload
        let mut m = random_model(403);
        for l in m.layers.iter_mut() {
            for p in l.projs.iter_mut() {
                let t = p.dense_mut();
                let sc: Vec<f64> =
                    t.data.iter().map(|x| x.abs() as f64).collect();
                crate::prune::unstructured::mask_lowest(t, &sc, 0.7);
            }
        }
        let path = std::env::temp_dir().join("mosaic_export_rt.bin");
        export_model(&m, &path).unwrap();
        let loaded = load_encoded(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // projections arrive sealed, not as densified f32 copies
        assert!(loaded.is_compacted());
        assert!(loaded
            .layers
            .iter()
            .flat_map(|l| l.projs.iter())
            .all(|s| !s.is_dense_f32()));
        assert!(loaded.resident_bytes() < m.resident_bytes());
        // same structure, near-identical logits (f16 rounding only)
        assert_eq!(loaded.cfg.n_layers, m.cfg.n_layers);
        let toks: Vec<u16> = vec![1, 8, 3, 5];
        let a = forward_full(&m, &toks);
        let b = forward_full(&loaded, &toks);
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert!(
                (x - y).abs() < 5e-2 * (1.0 + x.abs()),
                "{x} vs {y}"
            );
        }
    }
}
