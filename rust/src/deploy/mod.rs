//! Post-Pruning Optimizer deployment formats (PC component 10: "convert
//! the model weights into different inference formats") — the on-disk
//! side of the paper's size story:
//!
//!   * DenseF32 — the working format (what the engine mmaps today);
//!   * DenseF16 — half-precision storage (Table II measures fp16 sizes);
//!   * SparseCsr — compressed rows for unstructured-pruned projections:
//!     a masked model whose *resident* bytes don't shrink still ships a
//!     smaller file (and is what a DeepSparse/CUTLASS-style backend
//!     would ingest).
//!
//! `choose_encoding` picks per projection: CSR when the zero fraction
//! pays for the index overhead, else dense f16.

pub mod f16;

use anyhow::Result;

use crate::model::config::Proj;
use crate::model::ModelWeights;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    DenseF32,
    DenseF16,
    SparseCsr,
}

/// Serialized size (bytes) of one tensor under an encoding.
pub fn encoded_bytes(t: &Tensor, e: Encoding) -> usize {
    match e {
        Encoding::DenseF32 => 4 * t.numel(),
        Encoding::DenseF16 => 2 * t.numel(),
        Encoding::SparseCsr => {
            let nnz = t.numel() - t.zero_count();
            // row pointers (u32) + column indices (u16) + f16 values
            4 * (t.rows() + 1) + 2 * nnz + 2 * nnz
        }
    }
}

/// Pick the cheapest encoding for a tensor.
pub fn choose_encoding(t: &Tensor) -> Encoding {
    if encoded_bytes(t, Encoding::SparseCsr)
        < encoded_bytes(t, Encoding::DenseF16)
    {
        Encoding::SparseCsr
    } else {
        Encoding::DenseF16
    }
}

/// Encode a tensor; `decode` inverts (f16 rounding is lossy by design).
pub fn encode(t: &Tensor, e: Encoding) -> Vec<u8> {
    let mut out = Vec::with_capacity(encoded_bytes(t, e) + 16);
    match e {
        Encoding::DenseF32 => {
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Encoding::DenseF16 => {
            for &v in &t.data {
                out.extend_from_slice(&f16::to_bits(v).to_le_bytes());
            }
        }
        Encoding::SparseCsr => {
            let (r, c) = (t.rows(), t.cols());
            let mut rowptr = Vec::with_capacity(r + 1);
            let mut cols: Vec<u16> = Vec::new();
            let mut vals: Vec<u16> = Vec::new();
            rowptr.push(0u32);
            for i in 0..r {
                for j in 0..c {
                    let v = t.data[i * c + j];
                    if v != 0.0 {
                        cols.push(j as u16);
                        vals.push(f16::to_bits(v));
                    }
                }
                rowptr.push(cols.len() as u32);
            }
            for p in rowptr {
                out.extend_from_slice(&p.to_le_bytes());
            }
            for cj in cols {
                out.extend_from_slice(&cj.to_le_bytes());
            }
            for v in vals {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    out
}

pub fn decode(
    bytes: &[u8],
    shape: &[usize],
    e: Encoding,
) -> Result<Tensor> {
    let numel: usize = shape.iter().product();
    let mut t = Tensor::zeros(shape);
    match e {
        Encoding::DenseF32 => {
            anyhow::ensure!(bytes.len() == 4 * numel, "f32 size");
            for (i, ch) in bytes.chunks_exact(4).enumerate() {
                t.data[i] =
                    f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
        }
        Encoding::DenseF16 => {
            anyhow::ensure!(bytes.len() == 2 * numel, "f16 size");
            for (i, ch) in bytes.chunks_exact(2).enumerate() {
                t.data[i] =
                    f16::from_bits(u16::from_le_bytes([ch[0], ch[1]]));
            }
        }
        Encoding::SparseCsr => {
            let (r, c) = (shape[0], shape[1]);
            let ptr_bytes = 4 * (r + 1);
            anyhow::ensure!(bytes.len() >= ptr_bytes, "csr header");
            let mut rowptr = Vec::with_capacity(r + 1);
            for ch in bytes[..ptr_bytes].chunks_exact(4) {
                rowptr.push(u32::from_le_bytes([
                    ch[0], ch[1], ch[2], ch[3],
                ]) as usize);
            }
            let nnz = *rowptr.last().unwrap();
            let cols_off = ptr_bytes;
            let vals_off = cols_off + 2 * nnz;
            anyhow::ensure!(
                bytes.len() == vals_off + 2 * nnz,
                "csr payload size"
            );
            for i in 0..r {
                for k in rowptr[i]..rowptr[i + 1] {
                    let cb = &bytes[cols_off + 2 * k..cols_off + 2 * k + 2];
                    let vb = &bytes[vals_off + 2 * k..vals_off + 2 * k + 2];
                    let j = u16::from_le_bytes([cb[0], cb[1]]) as usize;
                    anyhow::ensure!(j < c, "csr col oob");
                    t.data[i * c + j] = f16::from_bits(
                        u16::from_le_bytes([vb[0], vb[1]]),
                    );
                }
            }
        }
    }
    Ok(t)
}

/// Total shipped size of a model under per-projection `choose_encoding`
/// (embeddings/norms/head stay dense f16).
pub fn shipped_bytes(m: &ModelWeights) -> usize {
    let mut total = 2
        * (m.embed.numel()
            + m.lm_head.numel()
            + m.final_norm.len());
    for l in &m.layers {
        total += 2 * (l.attn_norm.len() + l.ffn_norm.len());
        for &p in Proj::all().iter() {
            let t = l.proj(p);
            total += encoded_bytes(t, choose_encoding(t));
        }
    }
    total
}

/// Write the whole model in deployment format (header JSON + blobs).
pub fn export_model(m: &ModelWeights, path: &std::path::Path) -> Result<usize> {
    use crate::util::json::Json;
    let mut blobs: Vec<u8> = Vec::new();
    let mut entries = Vec::new();
    let mut push = |name: String, t: &Tensor, blobs: &mut Vec<u8>| {
        let e = if name.contains('.') {
            choose_encoding(t)
        } else {
            Encoding::DenseF16
        };
        let data = encode(t, e);
        let mut o = Json::obj();
        o.set("name", Json::str(&name));
        o.set(
            "shape",
            Json::from_f64s(
                &t.shape.iter().map(|&s| s as f64).collect::<Vec<_>>(),
            ),
        );
        o.set(
            "encoding",
            Json::str(match e {
                Encoding::DenseF32 => "f32",
                Encoding::DenseF16 => "f16",
                Encoding::SparseCsr => "csr",
            }),
        );
        o.set("offset", Json::num(blobs.len() as f64));
        o.set("bytes", Json::num(data.len() as f64));
        blobs.extend_from_slice(&data);
        entries.push(o);
    };
    push("embed".into(), &m.embed, &mut blobs);
    for (li, l) in m.layers.iter().enumerate() {
        for &p in Proj::all().iter() {
            push(format!("l{li}.{}", p.name()), l.proj(p), &mut blobs);
        }
    }
    push("lm_head".into(), &m.lm_head, &mut blobs);
    let mut header = Json::obj();
    header.set("model", Json::str(&m.cfg.name));
    header.set("tensors", Json::Arr(entries));
    let hs = header.to_string();
    let mut file = Vec::new();
    file.extend_from_slice(&(hs.len() as u64).to_le_bytes());
    file.extend_from_slice(hs.as_bytes());
    file.extend_from_slice(&blobs);
    std::fs::write(path, &file)?;
    Ok(file.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::testutil::random_model;
    use crate::util::rng::Pcg32;

    fn rand_t(seed: u64, r: usize, c: usize) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        Tensor::new(
            (0..r * c).map(|_| rng.normal()).collect(),
            vec![r, c],
        )
    }

    #[test]
    fn f32_roundtrip_exact() {
        let t = rand_t(1, 7, 9);
        let b = encode(&t, Encoding::DenseF32);
        let t2 = decode(&b, &t.shape, Encoding::DenseF32).unwrap();
        assert_eq!(t.data, t2.data);
    }

    #[test]
    fn f16_roundtrip_close() {
        let t = rand_t(2, 8, 8);
        let b = encode(&t, Encoding::DenseF16);
        let t2 = decode(&b, &t.shape, Encoding::DenseF16).unwrap();
        for (a, b) in t.data.iter().zip(t2.data.iter()) {
            assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()));
        }
    }

    #[test]
    fn csr_roundtrip_preserves_pattern() {
        let mut t = rand_t(3, 10, 14);
        // zero 70%
        for (i, v) in t.data.iter_mut().enumerate() {
            if i % 10 < 7 {
                *v = 0.0;
            }
        }
        let b = encode(&t, Encoding::SparseCsr);
        let t2 = decode(&b, &t.shape, Encoding::SparseCsr).unwrap();
        for (a, b) in t.data.iter().zip(t2.data.iter()) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            } else {
                assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()));
            }
        }
        assert!(b.len() < encoded_bytes(&t, Encoding::DenseF16));
    }

    #[test]
    fn choose_encoding_crossover() {
        let dense = rand_t(4, 16, 16);
        assert_eq!(choose_encoding(&dense), Encoding::DenseF16);
        let mut sparse = dense.clone();
        for (i, v) in sparse.data.iter_mut().enumerate() {
            if i % 5 != 0 {
                *v = 0.0; // 80% zeros
            }
        }
        assert_eq!(choose_encoding(&sparse), Encoding::SparseCsr);
    }

    #[test]
    fn shipped_bytes_shrink_with_unstructured_pruning() {
        // the paper: UP doesn't shrink the RESIDENT model — but the
        // deployment FILE should shrink via CSR
        let m = random_model(401);
        let dense_file = shipped_bytes(&m);
        let mut pruned = m.clone();
        for l in pruned.layers.iter_mut() {
            for p in l.projs.iter_mut() {
                let sc: Vec<f64> =
                    p.data.iter().map(|x| x.abs() as f64).collect();
                crate::prune::unstructured::mask_lowest(p, &sc, 0.8);
            }
        }
        assert_eq!(pruned.model_bytes(), m.model_bytes());
        assert!(
            shipped_bytes(&pruned) < dense_file,
            "CSR file must shrink: {} vs {dense_file}",
            shipped_bytes(&pruned)
        );
    }

    #[test]
    fn export_writes_parseable_file() {
        let m = random_model(402);
        let path = std::env::temp_dir().join("mosaic_export_test.bin");
        let n = export_model(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), n);
        let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap())
            as usize;
        let header = std::str::from_utf8(&bytes[8..8 + hlen]).unwrap();
        let j = crate::util::json::Json::parse(header).unwrap();
        let tensors = j.get("tensors").unwrap().as_arr().unwrap();
        assert_eq!(tensors.len(), 1 + m.cfg.n_layers * 7 + 1);
        std::fs::remove_file(&path).ok();
    }
}
