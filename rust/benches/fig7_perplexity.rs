//! Fig. 7: WikiText-2 + PTB perplexity for all five models under
//! global / layer / projection pruning, sparsity 0–80 %.
//! Paper shape: projection lowest everywhere, gap widens with sparsity.

use mosaic::bench_support::{header, rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::perplexity_native;
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig7_perplexity",
                           "PPL vs sparsity, 3 uniformity methods");
    let models: &[&str] = if Bench::fast() {
        &["tl1_7"]
    } else {
        &["tl31", "tl3", "tl2_13", "tl1_7", "tvic"]
    };
    let sparsities = [0.0, 0.2, 0.4, 0.6, 0.8];
    let samples = Bench::samples();
    for name in models {
        let mut mo = Mosaic::load(name)?;
        let seq = mo.dense.cfg.ctx.min(64);
        let wt = mo.store.split("wikitext2s")?;
        let ptb = mo.store.split("ptbs")?;
        println!("\n-- {} ({}) --", name, mo.dense.cfg.proxy_for);
        header(&["sparsity", "method", "wt2s-ppl", "ptbs-ppl"]);
        for &p in &sparsities {
            for u in [Uniformity::Global, Uniformity::Layer,
                      Uniformity::Projection] {
                let m = if p == 0.0 {
                    mo.dense.clone()
                } else {
                    // the paper's setup: SparseGPT pruner for all three
                    // uniformity methods
                    mo.prune(p, u, Category::Unstructured, samples)?.0
                };
                let a = perplexity_native(&m, &wt, seq, 16);
                let c = perplexity_native(&m, &ptb, seq, 16);
                println!(
                    "{:>12.0}%{:>12}{:>12.2}{:>12.2}",
                    p * 100.0, u.name(), a, c
                );
                b.row("series", rec(&[
                    ("model", Json::str(name)),
                    ("sparsity", Json::num(p)),
                    ("method", Json::str(u.name())),
                    ("wikitext2s_ppl", Json::num(a)),
                    ("ptbs_ppl", Json::num(c)),
                ]));
                if p == 0.0 {
                    break; // dense is method-independent
                }
            }
        }
    }
    b.finish();
    Ok(())
}
