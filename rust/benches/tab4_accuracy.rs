//! Table IV (+ appendix Tables X, XI): mean zero-shot accuracy of the
//! LLaMa-3.1-8B and LLaMa-2-13B proxies under global / layer /
//! projection pruning at 0–80 % sparsity, with the per-task breakdown.
//! Paper shape: projection highest at every sparsity; the gap explodes
//! at 80 % (e.g. 48.5 vs 36.9 for 13B); collapsed tasks fall to chance.

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::{mean_accuracy, per_task_accuracy};
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("tab4_accuracy",
                           "mean zero-shot accuracy vs sparsity");
    let models: &[&str] =
        if Bench::fast() { &["tl31"] } else { &["tl31", "tl2_13"] };
    let sparsities: &[f64] = if Bench::fast() {
        &[0.8]
    } else {
        &[0.2, 0.4, 0.6, 0.8]
    };
    let samples = Bench::samples();
    for name in models {
        let mut mo = Mosaic::load(name)?;
        println!("\n-- {} ({}) --", name, mo.dense.cfg.proxy_for);
        let dense = mean_accuracy(&mo.dense, &mo.store)?;
        println!("{:>10} {:>12} {:>8}", "sparsity", "method", "mean%");
        println!("{:>10} {:>12} {:>8.2}", "0%", "-", dense);
        b.row("series", rec(&[
            ("model", Json::str(name)),
            ("sparsity", Json::num(0.0)),
            ("method", Json::str("dense")),
            ("mean_acc", Json::num(dense)),
        ]));
        for &p in sparsities {
            for u in [Uniformity::Global, Uniformity::Layer,
                      Uniformity::Projection] {
                let m =
                    mo.prune(p, u, Category::Unstructured, samples)?.0;
                let acc = mean_accuracy(&m, &mo.store)?;
                let per = per_task_accuracy(&m, &mo.store)?;
                println!("{:>9.0}% {:>12} {:>8.2}",
                         p * 100.0, u.name(), acc);
                let mut tasks = Json::obj();
                for (t, a) in &per {
                    tasks.set(t, Json::num(*a));
                }
                b.row("series", rec(&[
                    ("model", Json::str(name)),
                    ("sparsity", Json::num(p)),
                    ("method", Json::str(u.name())),
                    ("mean_acc", Json::num(acc)),
                    ("per_task", tasks),
                ]));
            }
        }
    }
    b.finish();
    Ok(())
}
