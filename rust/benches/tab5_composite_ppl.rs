//! Table V: LLaMa-7B proxy perplexity for unstructured / composite /
//! structured projection pruning at 0–80 %.
//! Paper shape: UP degrades gently; composite sits between; structured
//! collapses past 40 % (up to 36x worse than composite).

use mosaic::bench_support::{header, rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::perplexity_native;
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("tab5_composite_ppl",
                           "UP vs composite vs SP perplexity");
    let mut mo = Mosaic::load("tl1_7")?;
    let seq = mo.dense.cfg.ctx.min(64);
    let samples = Bench::samples();
    let sparsities: &[f64] = if Bench::fast() {
        &[0.4, 0.8]
    } else {
        &[0.2, 0.4, 0.6, 0.8]
    };
    for split in ["wikitext2s", "ptbs"] {
        let stream = mo.store.split(split)?;
        let dense = perplexity_native(&mo.dense, &stream, seq, 16);
        println!("\n-- {split} (dense {dense:.2}) --");
        header(&["sparsity", "unstruct", "composite", "structured"]);
        for &p in sparsities {
            let mut row = vec![p * 100.0];
            for c in [Category::Unstructured, Category::Composite,
                      Category::Structured] {
                let m = mo.prune(p, Uniformity::Projection, c, samples)?.0;
                let ppl = perplexity_native(&m, &stream, seq, 16);
                row.push(ppl);
                b.row("series", rec(&[
                    ("split", Json::str(split)),
                    ("sparsity", Json::num(p)),
                    ("category", Json::str(c.name())),
                    ("ppl", Json::num(ppl)),
                ]));
            }
            mosaic::bench_support::rowf(&row);
        }
    }
    b.finish();
    Ok(())
}
