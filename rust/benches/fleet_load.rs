//! Fleet capacity under open-loop load (`make bench-fleet`). An
//! arrival-scheduled request stream — NOT closed-loop: the schedule
//! never waits for completions, so queueing delay shows up in the
//! latency percentiles instead of silently throttling the offered
//! rate — drives a routed fleet (dense parent + cold sealed-70
//! canary) over real TCP at sweeping rates:
//!
//! * per rate: completed/offered, p50/p95/p99 measured from the
//!   *scheduled* arrival instant, and delivered tok/s;
//! * the **saturation knee**: the first offered rate where completions
//!   drop below 90% or p99 blows past 20x the lowest-rate baseline;
//! * scale-to-zero costs stay visible: the canary backend starts
//!   Cold (its first probe's `queue_ms` is the wake latency) and must
//!   serve bit-identical greedy output after the post-sweep
//!   idle-unload → re-wake cycle.
//!
//! Rows merge into `BENCH_serve.json` (section "fleet*"), alongside
//! the serve_throughput and chaos rows, for cross-PR perf tracking.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use mosaic::bench_support::{header, rec, Bench};
use mosaic::data::trace::{generate, percentiles, Arrival, TraceConfig};
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::lifecycle::LifecycleState;
use mosaic::serve::router::parse_route;
use mosaic::serve::{ModelRegistry, ServeConfig, Server};
use mosaic::util::json::Json;

const ROUTE: &str = "chat";
const PROBE: [u16; 4] = [1, 9, 4, 7];

fn dense() -> ModelWeights {
    random_model_sized(9, 2, 64, 4, 176, 128, 64)
}

fn sealed70(dense: &ModelWeights) -> ModelWeights {
    let mut m = dense.clone();
    for l in m.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    m.compact();
    m
}

/// One fixed greedy request addressed directly at `model`; returns
/// the token stream (parity checks) and queue_ms (wake latency when
/// the backend was Cold).
fn probe(addr: SocketAddr, model: &str) -> (Vec<u16>, f64) {
    let mut c = Client::connect(addr).expect("connect");
    let r = c
        .generate(&GenRequest::greedy(&PROBE).max_new(12).model(model))
        .expect("probe");
    (r.tokens, r.queue_ms)
}

struct RateOut {
    offered: usize,
    completed: usize,
    p50: f64,
    p95: f64,
    p99: f64,
    tok_per_s: f64,
}

/// Open-loop drive at one offered rate: every request has its own
/// pre-connected client (connection setup outside the measured
/// window) and fires at its scheduled arrival regardless of how the
/// server is keeping up. Latency is measured from the *scheduled*
/// instant, so dispatch lag and queueing both count.
fn drive(addr: SocketAddr, rate: f64, n: usize) -> RateOut {
    let trace = generate(&TraceConfig {
        arrival: Arrival::Poisson,
        rate,
        n_requests: n,
        prompt_len_mean: 8,
        prompt_len_max: 16,
        max_new: 12,
        vocab: 120,
        seed: 42,
    });
    let clients: Vec<Client> = (0..n)
        .map(|_| Client::connect(addr).expect("connect"))
        .collect();
    let t0 = Instant::now();
    let handles: Vec<_> = clients
        .into_iter()
        .zip(trace)
        .map(|(mut c, item)| {
            std::thread::spawn(move || {
                let sched = t0 + Duration::from_secs_f64(item.at_s);
                let now = Instant::now();
                if sched > now {
                    std::thread::sleep(sched - now);
                }
                let r = c.generate(
                    &GenRequest::greedy(&item.prompt)
                        .max_new(item.max_new)
                        .model(ROUTE),
                );
                let lat_ms = Instant::now()
                    .saturating_duration_since(sched)
                    .as_secs_f64()
                    * 1e3;
                r.ok().map(|r| (lat_ms, r.tokens.len()))
            })
        })
        .collect();
    let mut lats = Vec::new();
    let mut tokens = 0usize;
    for h in handles {
        if let Some((lat, t)) = h.join().expect("load worker") {
            lats.push(lat);
            tokens += t;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let completed = lats.len();
    let (p50, p95, p99) = percentiles(lats);
    RateOut {
        offered: n,
        completed,
        p50,
        p95,
        p99,
        tok_per_s: tokens as f64 / wall,
    }
}

fn main() -> anyhow::Result<()> {
    let mut b =
        Bench::new("fleet_load", "fleet capacity under open-loop load");
    let d = dense();
    let s70 = sealed70(&d);
    let path = std::env::temp_dir().join("fleet_load_s70.mosaic");
    mosaic::deploy::export_model(&s70, &path)?;
    let mut reg = ModelRegistry::new();
    reg.register("dense", d)?;
    reg.register_cold("s70", &path)?;
    let srv = Server::start_registry(
        reg,
        ServeConfig {
            max_batch: 8,
            max_queue: 1024,
            default_model: Some("dense".into()),
            routes: vec![parse_route("chat=dense:70,s70:30")?],
            route_seed: 42,
            idle_ms: Some(300),
            ..Default::default()
        },
        0,
    )?;
    let mut rows: Vec<Json> = Vec::new();

    // ---- cold-wake probes: the canary's first queue_ms IS the wake
    // latency (artifact load + spawn); these token streams are the
    // parity reference for the post-sweep re-wake check
    println!("— cold-wake probes —");
    header(&["backend", "queue-ms"]);
    let mut pre = Vec::new();
    for backend in ["dense", "s70"] {
        let (tokens, queue_ms) = probe(srv.addr, backend);
        println!("{backend:>12}{queue_ms:>12.2}");
        rows.push(rec(&[
            ("section", Json::str("fleet_wake")),
            ("backend", Json::str(backend)),
            ("queue_ms", Json::num(queue_ms)),
        ]));
        pre.push((backend, tokens));
    }

    // ---- the rate sweep
    let (rates, n) = if Bench::fast() {
        (vec![100.0, 800.0], 24)
    } else {
        (vec![50.0, 200.0, 800.0, 2000.0], 96)
    };
    println!("\n— open-loop sweep ({n} requests/rate) —");
    header(&["rate/s", "done", "p50-ms", "p95-ms", "p99-ms", "tok/s"]);
    let mut knee: Option<f64> = None;
    let mut base_p99: Option<f64> = None;
    for rate in rates {
        let out = drive(srv.addr, rate, n);
        println!(
            "{rate:>12.0}{:>12}{:>12.2}{:>12.2}{:>12.2}{:>12.0}",
            out.completed, out.p50, out.p95, out.p99, out.tok_per_s
        );
        let saturated = out.completed * 10 < out.offered * 9
            || base_p99.is_some_and(|b| out.p99 > 20.0 * b.max(0.1));
        if base_p99.is_none() {
            base_p99 = Some(out.p99);
        }
        if saturated && knee.is_none() {
            knee = Some(rate);
        }
        rows.push(rec(&[
            ("section", Json::str("fleet")),
            ("rate_offered", Json::num(rate)),
            ("offered", Json::num(out.offered as f64)),
            ("completed", Json::num(out.completed as f64)),
            ("p50_ms", Json::num(out.p50)),
            ("p95_ms", Json::num(out.p95)),
            ("p99_ms", Json::num(out.p99)),
            ("tok_per_s", Json::num(out.tok_per_s)),
        ]));
    }
    match knee {
        Some(r) => println!("  saturation knee at {r:.0} req/s"),
        None => println!("  no knee inside the swept range"),
    }

    // ---- idle-unload → re-wake parity: wait for the canary to
    // re-park Cold, probe both backends again, outputs must be
    // byte-identical to the pre-sweep reference
    let deadline = Instant::now() + Duration::from_secs(30);
    while srv.engine_lifecycle("s70") != Some(LifecycleState::Cold) {
        anyhow::ensure!(
            Instant::now() < deadline,
            "s70 never re-parked Cold after the sweep"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    for (backend, want) in &pre {
        let (tokens, _) = probe(srv.addr, backend);
        anyhow::ensure!(
            tokens == *want,
            "{backend}: output diverged across the sweep/unload cycle"
        );
    }
    println!("  parity: pre/post-sweep outputs identical");
    rows.push(rec(&[
        ("section", Json::str("fleet_knee")),
        ("knee_rate", knee.map_or(Json::Null, Json::num)),
        ("parity", Json::Bool(true)),
    ]));
    for r in &rows {
        b.row("fleet", r.clone());
    }
    srv.shutdown();
    let _ = std::fs::remove_file(&path);

    // ---- merge into BENCH_serve.json: replace prior fleet* rows,
    // keep everything the other serve benches wrote
    let mut kept: Vec<Json> = Vec::new();
    let mut out = Json::obj();
    out.set("bench", Json::str("serve_throughput"));
    if let Ok(prev) = std::fs::read_to_string("BENCH_serve.json") {
        if let Ok(j) = Json::parse(prev.trim()) {
            if let Some(name) = j.get("bench").and_then(|v| v.as_str()) {
                out.set("bench", Json::str(name));
            }
            if let Some(nr) = j.get("n_requests") {
                out.set("n_requests", nr.clone());
            }
            if let Some(rs) = j.get("rows").and_then(|r| r.as_arr()) {
                kept.extend(rs.iter().cloned().filter(|r| {
                    !r.get("section")
                        .and_then(|s| s.as_str())
                        .is_some_and(|s| s.starts_with("fleet"))
                }));
            }
        }
    }
    kept.extend(rows);
    out.set("rows", Json::Arr(kept));
    std::fs::write("BENCH_serve.json", out.to_string())?;
    println!("\n[merged fleet rows into BENCH_serve.json]");

    b.finish();
    Ok(())
}
