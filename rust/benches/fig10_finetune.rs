//! Fig. 10: LoRA fine-tuning train/eval loss curves for 80 %-pruned
//! models under global / layer / projection pruning.
//! Paper shape: the projection-pruned model starts lower and reaches
//! any given loss several times faster than global/layer.

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::finetune::{train_lora, LoraConfig};
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig10_finetune", "LoRA loss curves @80%");
    let models: &[&str] =
        if Bench::fast() { &["tl31"] } else { &["tl31", "tl2_13"] };
    let steps = if Bench::fast() { 20 } else { 80 };
    let samples = Bench::samples();
    for name in models {
        let mut mo = Mosaic::load(name)?;
        let (rows, n_rows, seq) = mo.finetune_rows()?;
        println!("\n-- {name} --");
        for u in [Uniformity::Global, Uniformity::Layer,
                  Uniformity::Projection] {
            let (pruned, _) =
                mo.prune(0.8, u, Category::Unstructured, samples)?;
            let cfg = LoraConfig { steps, ..Default::default() };
            let rt = mo.runtime()?;
            rt.set_weights(&pruned)?;
            let res = train_lora(rt, &rows, n_rows, seq, &cfg)?;
            let first = res.train_curve.first().unwrap().1;
            let last = res.train_curve.last().unwrap().1;
            println!(
                "{:>11}: train {first:.3} -> {last:.3}, eval {:.3} -> \
                 {:.3} ({:.1}s)",
                u.name(),
                res.eval_curve.first().unwrap().1,
                res.eval_curve.last().unwrap().1,
                res.wall_s
            );
            b.row("series", rec(&[
                ("model", Json::str(name)),
                ("method", Json::str(u.name())),
                ("train_curve", Json::Arr(
                    res.train_curve.iter()
                        .map(|(s, l)| Json::from_f64s(&[*s as f64, *l]))
                        .collect())),
                ("eval_curve", Json::Arr(
                    res.eval_curve.iter()
                        .map(|(s, l)| Json::from_f64s(&[*s as f64, *l]))
                        .collect())),
                ("wall_s", Json::num(res.wall_s)),
            ]));
        }
    }
    b.finish();
    Ok(())
}
