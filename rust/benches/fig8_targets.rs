//! Fig. 8: the pruning targets each method assigns across layers and
//! projections of the LLaMa-3.1-8B proxy at p = 80 %.
//! Paper shape: global is a flat line; layer varies per layer;
//! projection varies per projection with the widest range.

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::model::config::PROJS;
use mosaic::prune::{plan, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig8_targets",
                           "per-layer/projection targets at p=0.8");
    let mut mo = Mosaic::load("tl31")?;
    let p = 0.8;
    let samples = Bench::samples();
    for u in [Uniformity::Global, Uniformity::Layer,
              Uniformity::Projection] {
        let rank = mo.global_rank(u, samples)?;
        let pl = plan(&rank, p, u);
        println!("\n-- {} --", u.name());
        let flat: Vec<f64> =
            pl.targets.iter().flatten().cloned().collect();
        let lo = flat.iter().cloned().fold(1.0f64, f64::min);
        let hi = flat.iter().cloned().fold(0.0f64, f64::max);
        println!("range: {:.1}%..{:.1}%  mean {:.2}%",
                 lo * 100.0, hi * 100.0, pl.mean_target() * 100.0);
        for (l, row) in pl.targets.iter().enumerate() {
            let cells: Vec<String> = row
                .iter()
                .zip(PROJS.iter())
                .map(|(t, n)| format!("{n}:{:.0}%", t * 100.0))
                .collect();
            println!("  layer {l:2}: {}", cells.join(" "));
            b.row(u.name(), rec(&[
                ("layer", Json::num(l as f64)),
                ("targets", Json::from_f64s(row)),
            ]));
        }
        b.row("ranges", rec(&[
            ("method", Json::str(u.name())),
            ("lo", Json::num(lo)),
            ("hi", Json::num(hi)),
        ]));
    }
    b.finish();
    Ok(())
}
