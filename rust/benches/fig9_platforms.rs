//! Fig. 9: inference latency and GPU memory of the LLaMa-7B proxy on
//! platforms P1–P5 for pruning targets 0–80 % and the three categories.
//!
//! Two anchors per configuration:
//!   * *measured* — the native rust engine on this host (real wall time,
//!     real byte counts);
//!   * *simulated* — the platform roofline model fed paper-scale bytes
//!     (the tiny model's structural fractions scaled to 6.74 B params).
//!
//! Paper shape: UP latency/memory flat; composite and SP shrink both;
//! offload cliff on P3; P5 cannot run dense/UP at all.

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::measure_native;
use mosaic::platform::{self, can_run, ModelProfile, Workload};
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig9_platforms",
                           "latency + memory across P1-P5");
    let mut mo = Mosaic::load("tl1_7")?;
    let samples = Bench::samples();
    let dense_bytes = mo.dense.model_bytes() as f64;
    let paper_params = 6.74e9;
    let sparsities: &[f64] =
        if Bench::fast() { &[0.8] } else { &[0.2, 0.4, 0.6, 0.8] };

    // dense reference rows
    let dense_prof = ModelProfile::paper_scale(paper_params, 32, 4096, 32);
    for pf in platform::testbed() {
        let w = if pf.name == "P5" { Workload::edge() }
                else { Workload::mlperf() };
        let runnable = can_run(&pf, &dense_prof, &w);
        let sim = platform::simulate(&pf, &dense_prof, &w);
        println!(
            "{} dense: {}",
            pf.name,
            if runnable {
                format!("sim {:.2}s / {} GB{}", sim.latency_s,
                        sim.mem_bytes >> 30,
                        if sim.offloading { " (offloading)" } else { "" })
            } else {
                "CANNOT RUN".to_string()
            }
        );
        b.row("series", rec(&[
            ("platform", Json::str(pf.name)),
            ("category", Json::str("dense")),
            ("sparsity", Json::num(0.0)),
            ("runnable", Json::Bool(runnable)),
            ("latency_s", Json::num(sim.latency_s)),
            ("mem_mb", Json::num((sim.mem_bytes >> 20) as f64)),
            ("offloading", Json::Bool(sim.offloading)),
        ]));
    }

    for &p in sparsities {
        for c in [Category::Unstructured, Category::Composite,
                  Category::Structured] {
            let (m, _) = mo.prune(p, Uniformity::Projection, c, samples)?;
            let perf = measure_native(&m, 32, 8, 3);
            // paper-scale profile: structural byte fraction carries over
            let frac = m.model_bytes() as f64 / dense_bytes;
            let live_frac = m.live_proj_params() as f64
                / mo.dense.live_proj_params() as f64;
            let kept_head_frac = m.layers[0].kept_heads.len() as f64
                / m.cfg.n_heads as f64;
            let mut prof = ModelProfile::paper_scale(
                paper_params * frac, 32, (4096.0 * kept_head_frac) as usize,
                (32.0 * kept_head_frac) as usize);
            prof.live_params = (paper_params * live_frac) as u64;
            println!("\np={:.0}% {}: host {:.4}s, {} KB", p * 100.0,
                     c.name(), perf.latency_s, perf.model_bytes / 1024);
            for pf in platform::testbed() {
                let w = if pf.name == "P5" { Workload::edge() }
                        else { Workload::mlperf() };
                let runnable = can_run(&pf, &prof, &w);
                let sim = platform::simulate(&pf, &prof, &w);
                println!(
                    "  {}: {}",
                    pf.name,
                    if runnable {
                        format!("sim {:.2}s / {} GB{}", sim.latency_s,
                                sim.mem_bytes >> 30,
                                if sim.offloading { " (offloading)" }
                                else { "" })
                    } else {
                        "CANNOT RUN".into()
                    }
                );
                b.row("series", rec(&[
                    ("platform", Json::str(pf.name)),
                    ("category", Json::str(c.name())),
                    ("sparsity", Json::num(p)),
                    ("runnable", Json::Bool(runnable)),
                    ("latency_s", Json::num(sim.latency_s)),
                    ("mem_mb", Json::num((sim.mem_bytes >> 20) as f64)),
                    ("offloading", Json::Bool(sim.offloading)),
                    ("host_latency_s", Json::num(perf.latency_s)),
                    ("host_model_bytes",
                     Json::num(perf.model_bytes as f64)),
                ]));
            }
        }
    }
    b.finish();
    Ok(())
}
