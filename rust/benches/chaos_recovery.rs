//! Supervision overhead + crash recovery (`make bench-chaos`, feature
//! "chaos"). Two questions the robustness layer must answer with
//! numbers:
//!
//! * **overhead** — at 0 % injected faults, what do the panic
//!   boundary, inflight ledger and health checks cost? Compared by
//!   driving the same model (a) through the full supervised server and
//!   (b) through a bare `engine_loop` thread with no supervisor wrap.
//!   The delta must be negligible (the ledger is one mutex op per
//!   request, not per token).
//! * **recovery** — after an injected engine panic, how long until the
//!   respawned engine serves again, and at what tok/s?
//!
//! Rows merge into `BENCH_serve.json` (section "chaos*"), alongside
//! the serve_throughput rows, for cross-PR perf tracking.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use mosaic::bench_support::{header, rec, Bench};
use mosaic::data::trace::{generate, percentiles, Arrival, TraceConfig};
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::serve::fault::{self, FaultPlan};
use mosaic::serve::{
    engine_loop, wait_reply, Ctl, ModelRegistry, Request, ServeConfig,
    ServeStats, Server, SharedRx, SubmitSpec,
};
use mosaic::util::json::Json;

const MODEL: &str = "chaos-bench";

fn cfg() -> ServeConfig {
    ServeConfig {
        max_batch: 4,
        max_queue: 256,
        default_model: Some(MODEL.into()),
        max_restarts: 100,
        restart_backoff_ms: 1,
        ..Default::default()
    }
}

fn model() -> mosaic::model::ModelWeights {
    random_model_sized(9, 2, 64, 4, 176, 128, 64)
}

fn trace_items(n: usize) -> Vec<mosaic::data::trace::TraceItem> {
    generate(&TraceConfig {
        arrival: Arrival::Batch,
        rate: 100.0,
        n_requests: n,
        prompt_len_mean: 8,
        prompt_len_max: 16,
        max_new: 12,
        ..Default::default()
    })
}

struct DriveOut {
    tok_per_s: f64,
    p99_ms: f64,
}

/// Saturate the supervised server with `trace` and measure tok/s +
/// end-to-end p99.
fn drive_supervised(
    srv: &Server,
    trace: &[mosaic::data::trace::TraceItem],
) -> DriveOut {
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for item in trace {
        let sent = Instant::now();
        if let Ok(rx) = srv
            .submit_spec(SubmitSpec::greedy(&item.prompt, item.max_new))
        {
            pending.push((sent, rx));
        }
    }
    let mut lat = Vec::new();
    let mut tokens = 0usize;
    for (sent, rx) in pending {
        if let Ok(r) = wait_reply(&rx, Duration::from_secs(60)) {
            lat.push(sent.elapsed().as_secs_f64() * 1e3);
            tokens += r.tokens.len();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (_, _, p99) = percentiles(lat);
    DriveOut { tok_per_s: tokens as f64 / wall, p99_ms: p99 }
}

/// The unsupervised baseline: one bare `engine_loop` thread, no panic
/// boundary, no supervisor — requests hand-delivered to its queue.
fn drive_raw(
    trace: &[mosaic::data::trace::TraceItem],
) -> DriveOut {
    let c = cfg();
    let (tx, rx) = mpsc::sync_channel::<Request>(c.max_queue);
    let rx = SharedRx::new(rx);
    let stats = Arc::new(ServeStats::default());
    let ctl = Ctl::fresh();
    let engine = {
        let (m, name, c2, stats, ctl) = (
            Arc::new(model()),
            Arc::new(MODEL.to_string()),
            c.clone(),
            stats.clone(),
            ctl.clone(),
        );
        std::thread::spawn(move || {
            engine_loop(m, name, c2, &rx, stats, ctl, 1)
        })
    };
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for (i, item) in trace.iter().enumerate() {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            id: i as u64,
            prompt: item.prompt.clone(),
            max_new: item.max_new,
            sampling: None,
            stop_tokens: Vec::new(),
            stream: false,
            spec_k: None,
            deadline: None,
            route: None,
            enqueued: Instant::now(),
            reply: rtx,
        };
        stats.queue_depth.fetch_add(1, Ordering::Relaxed);
        let sent = Instant::now();
        if tx.send(req).is_ok() {
            pending.push((sent, rrx));
        }
    }
    let mut lat = Vec::new();
    let mut tokens = 0usize;
    for (sent, rrx) in pending {
        if let Ok(r) = wait_reply(&rrx, Duration::from_secs(60)) {
            lat.push(sent.elapsed().as_secs_f64() * 1e3);
            tokens += r.tokens.len();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(tx); // disconnect → engine exits
    engine.join().expect("raw engine must not panic at 0% faults");
    let (_, _, p99) = percentiles(lat);
    DriveOut { tok_per_s: tokens as f64 / wall, p99_ms: p99 }
}

fn start_server() -> Server {
    let mut reg = ModelRegistry::new();
    reg.register(MODEL, model()).expect("register");
    Server::start_registry(reg, cfg(), 0).expect("start")
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new(
        "chaos_recovery",
        "supervision overhead + crash recovery",
    );
    let n = if Bench::fast() { 16 } else { 48 };
    let trace = trace_items(n);

    // ---- overhead at 0% faults: supervised vs raw engine thread
    println!("— supervision overhead (0% faults) —");
    header(&["mode", "tok/s", "p99-ms"]);
    let srv = start_server();
    let sup = drive_supervised(&srv, &trace);
    let raw = drive_raw(&trace);
    let mut rows: Vec<Json> = Vec::new();
    for (mode, d) in [("supervised", &sup), ("raw-engine", &raw)] {
        println!("{mode:>12}{:>12.0}{:>12.2}", d.tok_per_s, d.p99_ms);
        let row = rec(&[
            ("section", Json::str("chaos_overhead")),
            ("mode", Json::str(mode)),
            ("tok_per_s", Json::num(d.tok_per_s)),
            ("p99_ms", Json::num(d.p99_ms)),
        ]);
        b.row("chaos_overhead", row.clone());
        rows.push(row);
    }
    let overhead_pct = if raw.tok_per_s > 0.0 {
        (raw.tok_per_s - sup.tok_per_s) / raw.tok_per_s * 100.0
    } else {
        0.0
    };
    println!("  supervision throughput cost: {overhead_pct:.1}%");

    // ---- recovery after an injected crash: panic the engine mid-
    // flight, then time how long until a fresh request completes on
    // the respawned engine
    println!("\n— crash recovery —");
    header(&["phase", "value"]);
    let plan = Arc::new(FaultPlan::new().panic_at(fault::CP_STEP, 4));
    let guard = fault::arm_guard(MODEL, plan);
    let mut pending = Vec::new();
    for item in trace.iter().take(8) {
        if let Ok(rx) = srv
            .submit_spec(SubmitSpec::greedy(&item.prompt, item.max_new))
        {
            pending.push(rx);
        }
    }
    // the panic lands while these drain; note when the first error
    // (the crash becoming externally visible) arrives
    let mut t_crash: Option<Instant> = None;
    for rx in pending {
        match wait_reply(&rx, Duration::from_secs(60)) {
            Ok(_) => {}
            Err(_) => {
                t_crash.get_or_insert_with(Instant::now);
            }
        }
    }
    drop(guard);
    let t_crash = t_crash.unwrap_or_else(Instant::now);
    // first successful reply on the respawned engine = recovered
    let recovery_ms = loop {
        let rx = srv.submit_spec(SubmitSpec::greedy(&[1, 5, 9], 4))?;
        if wait_reply(&rx, Duration::from_secs(60)).is_ok() {
            break t_crash.elapsed().as_secs_f64() * 1e3;
        }
        std::thread::sleep(Duration::from_millis(2));
    };
    let post = drive_supervised(&srv, &trace);
    let stats = srv.model_stats(MODEL).expect("stats");
    let panics = stats.engine_panics.load(Ordering::Relaxed);
    println!("{:>12}{recovery_ms:>12.1}", "recover-ms");
    println!("{:>12}{:>12.0}", "post-tok/s", post.tok_per_s);
    println!("{:>12}{panics:>12}", "panics");
    let row = rec(&[
        ("section", Json::str("chaos_recovery")),
        ("recovery_ms", Json::num(recovery_ms)),
        ("post_tok_per_s", Json::num(post.tok_per_s)),
        ("engine_panics", Json::num(panics as f64)),
    ]);
    b.row("chaos_recovery", row.clone());
    rows.push(row);
    srv.shutdown();

    // ---- merge into BENCH_serve.json: replace prior chaos* rows,
    // keep everything serve_throughput wrote
    let mut kept: Vec<Json> = Vec::new();
    let mut out = Json::obj();
    out.set("bench", Json::str("serve_throughput"));
    if let Ok(prev) = std::fs::read_to_string("BENCH_serve.json") {
        if let Ok(j) = Json::parse(prev.trim()) {
            if let Some(name) = j.get("bench").and_then(|v| v.as_str()) {
                out.set("bench", Json::str(name));
            }
            if let Some(nr) = j.get("n_requests") {
                out.set("n_requests", nr.clone());
            }
            if let Some(rs) = j.get("rows").and_then(|r| r.as_arr()) {
                kept.extend(rs.iter().cloned().filter(|r| {
                    !r.get("section")
                        .and_then(|s| s.as_str())
                        .is_some_and(|s| s.starts_with("chaos"))
                }));
            }
        }
    }
    kept.extend(rows);
    out.set("rows", Json::Arr(kept));
    std::fs::write("BENCH_serve.json", out.to_string())?;
    println!("\n[merged chaos rows into BENCH_serve.json]");

    b.finish();
    Ok(())
}
