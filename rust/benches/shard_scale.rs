//! Shard scaling under closed-loop load (`make bench-shards`). Two
//! questions the sharded serving plane must answer with numbers:
//!
//! * **replica scaling** — with per-engine width capped (max_batch 2,
//!   the single-engine bottleneck), how does delivered tok/s grow at
//!   shard widths N ∈ {1, 2, 4}? Near-linear at N=2 is the
//!   acceptance bar (≥ 1.6×); every width's greedy output is
//!   parity-checked against the unsharded engine before its row is
//!   recorded.
//! * **pipeline overhead** — what does the stage-boundary activation
//!   handoff cost? Single engine vs 2- and 3-stage layer-range
//!   pipelines over the same weights, same load, parity-checked.
//!
//! Rows merge into `BENCH_serve.json` (section "shard*"), alongside
//! the serve_throughput / chaos / fleet rows, for cross-PR perf
//! tracking.

use std::net::SocketAddr;
use std::time::Instant;

use mosaic::bench_support::{header, rec, Bench};
use mosaic::data::trace::percentiles;
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::{ModelRegistry, ServeConfig, Server, ShardPlan};
use mosaic::util::json::Json;

const MODEL: &str = "m";
const PROBE: [u16; 4] = [1, 9, 4, 7];

/// Four layers so the pipeline splits have real work per stage.
fn model() -> ModelWeights {
    random_model_sized(11, 4, 64, 4, 176, 128, 64)
}

fn start_with(plan: ShardPlan) -> Server {
    let mut reg = ModelRegistry::new();
    reg.register_sharded(MODEL, model(), plan).expect("register");
    Server::start_registry(
        reg,
        ServeConfig {
            // width 2 per engine: the single-engine ceiling replica
            // sharding is supposed to lift
            max_batch: 2,
            max_queue: 1024,
            default_model: Some(MODEL.into()),
            ..Default::default()
        },
        0,
    )
    .expect("start server")
}

fn probe(addr: SocketAddr) -> Vec<u16> {
    let mut c = Client::connect(addr).expect("connect");
    c.generate(&GenRequest::greedy(&PROBE).max_new(12).model(MODEL))
        .expect("probe")
        .tokens
}

struct DriveOut {
    tok_per_s: f64,
    p95_ms: f64,
}

/// Closed-loop drive: `clients` concurrent connections, each issuing
/// `per` sequential greedy requests. Wall-clock covers the whole
/// burst, so tok/s reflects delivered group capacity.
fn drive(addr: SocketAddr, clients: usize, per: usize) -> DriveOut {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                let mut lats = Vec::new();
                let mut tokens = 0usize;
                for r in 0..per {
                    let prompt = [
                        1 + ((ci + r) % 7) as u16,
                        9,
                        4 + ((ci * 3 + r) % 5) as u16,
                    ];
                    let s = Instant::now();
                    let reply = c
                        .generate(
                            &GenRequest::greedy(&prompt)
                                .max_new(16)
                                .model(MODEL),
                        )
                        .expect("generate");
                    lats.push(s.elapsed().as_secs_f64() * 1e3);
                    tokens += reply.tokens.len();
                }
                (lats, tokens)
            })
        })
        .collect();
    let mut lats = Vec::new();
    let mut tokens = 0usize;
    for h in handles {
        let (l, t) = h.join().expect("load worker");
        lats.extend(l);
        tokens += t;
    }
    let wall = t0.elapsed().as_secs_f64();
    let (_, p95, _) = percentiles(lats);
    DriveOut { tok_per_s: tokens as f64 / wall, p95_ms: p95 }
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new(
        "shard_scale",
        "replica scaling + pipeline handoff overhead",
    );
    let (clients, per) = if Bench::fast() { (8, 4) } else { (16, 10) };
    let mut rows: Vec<Json> = Vec::new();

    // the unsharded engine: throughput baseline AND parity reference
    let single = start_with(ShardPlan::Single);
    let want = probe(single.addr);
    let base = drive(single.addr, clients, per);
    single.shutdown();

    println!("— replica scaling ({clients} clients × {per} reqs) —");
    header(&["shards", "tok/s", "p95-ms", "scale"]);
    println!(
        "{:>12}{:>12.0}{:>12.2}{:>12.2}",
        1, base.tok_per_s, base.p95_ms, 1.0
    );
    rows.push(rec(&[
        ("section", Json::str("shard")),
        ("mode", Json::str("replica")),
        ("shards", Json::num(1.0)),
        ("tok_per_s", Json::num(base.tok_per_s)),
        ("p95_ms", Json::num(base.p95_ms)),
        ("scale_vs_1", Json::num(1.0)),
        ("parity", Json::Bool(true)),
    ]));
    for n in [2usize, 4] {
        let srv = start_with(ShardPlan::Replica(n));
        let got = probe(srv.addr);
        anyhow::ensure!(
            got == want,
            "replica x{n} output diverged from unsharded"
        );
        let out = drive(srv.addr, clients, per);
        srv.shutdown();
        let scale = out.tok_per_s / base.tok_per_s.max(1e-9);
        println!(
            "{n:>12}{:>12.0}{:>12.2}{scale:>12.2}",
            out.tok_per_s, out.p95_ms
        );
        rows.push(rec(&[
            ("section", Json::str("shard")),
            ("mode", Json::str("replica")),
            ("shards", Json::num(n as f64)),
            ("tok_per_s", Json::num(out.tok_per_s)),
            ("p95_ms", Json::num(out.p95_ms)),
            ("scale_vs_1", Json::num(scale)),
            ("parity", Json::Bool(true)),
        ]));
    }

    println!("\n— pipeline handoff overhead —");
    header(&["stages", "tok/s", "p95-ms", "vs-single"]);
    for stages in [2usize, 3] {
        let srv = start_with(ShardPlan::Pipeline(stages));
        let got = probe(srv.addr);
        anyhow::ensure!(
            got == want,
            "pipeline x{stages} output diverged from unsharded"
        );
        let out = drive(srv.addr, clients, per);
        srv.shutdown();
        let ratio = out.tok_per_s / base.tok_per_s.max(1e-9);
        println!(
            "{stages:>12}{:>12.0}{:>12.2}{ratio:>12.2}",
            out.tok_per_s, out.p95_ms
        );
        rows.push(rec(&[
            ("section", Json::str("shard_pipe")),
            ("mode", Json::str("pipeline")),
            ("shards", Json::num(stages as f64)),
            ("tok_per_s", Json::num(out.tok_per_s)),
            ("p95_ms", Json::num(out.p95_ms)),
            ("vs_single", Json::num(ratio)),
            ("parity", Json::Bool(true)),
        ]));
    }
    for r in &rows {
        b.row("shard", r.clone());
    }

    // ---- merge into BENCH_serve.json: replace prior shard* rows,
    // keep everything the other serve benches wrote
    let mut kept: Vec<Json> = Vec::new();
    let mut out = Json::obj();
    out.set("bench", Json::str("serve_throughput"));
    if let Ok(prev) = std::fs::read_to_string("BENCH_serve.json") {
        if let Ok(j) = Json::parse(prev.trim()) {
            if let Some(name) = j.get("bench").and_then(|v| v.as_str()) {
                out.set("bench", Json::str(name));
            }
            if let Some(nr) = j.get("n_requests") {
                out.set("n_requests", nr.clone());
            }
            if let Some(rs) = j.get("rows").and_then(|r| r.as_arr()) {
                kept.extend(rs.iter().cloned().filter(|r| {
                    !r.get("section")
                        .and_then(|s| s.as_str())
                        .is_some_and(|s| s.starts_with("shard"))
                }));
            }
        }
    }
    kept.extend(rows);
    out.set("rows", Json::Arr(kept));
    std::fs::write("BENCH_serve.json", out.to_string())?;
    println!("\n[merged shard rows into BENCH_serve.json]");

    b.finish();
    Ok(())
}
