//! Model-production speed: sequential whole-model prune + end-of-pass
//! `compact()` vs the streaming layer-parallel pipeline at 1/2/4/8
//! workers — the systems claim behind the paper's "7.19× faster model
//! production" is about this stage, not serving.
//!
//! Artifact-free (random seeded model, native calibration capture).
//! For every pruner kind the bench reports per-stage times
//! (capture / rank / prune / seal), end-to-end wall, and the
//! production working-set high-water mark — the sequential reference's
//! working set is a full dense model clone, the pipeline's must stay
//! below it. Each pipeline run is parity-checked against the
//! sequential output before its row is recorded (a perf number for a
//! wrong model is worthless).
//!
//! Emits `BENCH_produce.json` for cross-PR perf tracking — run via
//! `make bench-produce`.

use std::time::Instant;

use mosaic::bench_support::{header, rec, Bench};
use mosaic::model::capture::capture_calibration;
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::prune::pipeline::{
    produce_with_snapshot, sequential_reference, ProduceOpts, PrunerKind,
};
use mosaic::prune::planner::PruningPlan;
use mosaic::prune::CompositeOpts;
use mosaic::util::json::Json;

fn identical(a: &ModelWeights, b: &ModelWeights) -> bool {
    a.layers.len() == b.layers.len()
        && a.layers.iter().zip(b.layers.iter()).all(|(x, y)| {
            x.kept_heads == y.kept_heads
                && x.kept_channels == y.kept_channels
                && x.projs == y.projs
        })
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new(
        "produce_speed",
        "sequential vs streaming layer-parallel model production",
    );
    let fast = Bench::fast();
    // ≥ 12 layers so even the 8-worker sweep streams (in-flight dense
    // layers always a minority of the model)
    let (layers, d_model, ff) =
        if fast { (12, 32, 64) } else { (16, 64, 128) };
    let vocab = 256;
    let src =
        random_model_sized(0xBE7, layers, d_model, 4, ff, vocab, 32);
    let p = 0.7;
    let pl = PruningPlan::uniform(layers, p);
    let samples: Vec<Vec<u16>> = (0..if fast { 2 } else { 4 })
        .map(|s| {
            (0..16)
                .map(|i| ((i * 13 + s * 29) % (vocab - 4) + 2) as u16)
                .collect()
        })
        .collect();
    let dense_bytes = src.model_bytes();
    b.set("layers", Json::num(layers as f64));
    b.set("d_model", Json::num(d_model as f64));
    b.set("p", Json::num(p));
    b.set("dense_bytes", Json::num(dense_bytes as f64));

    // shared snapshot: both paths read the same statistics, so rows
    // measure production, not calibration variance
    let t = Instant::now();
    let snap = capture_calibration(&src, &samples, true);
    let capture_ms = t.elapsed().as_secs_f64() * 1e3;
    let stats = &snap.stats;
    let hess = snap.hess.as_ref().expect("grams requested");
    println!("capture: {capture_ms:.1} ms (shared snapshot)");
    b.set("capture_ms", Json::num(capture_ms));

    let kinds = [
        PrunerKind::Magnitude,
        PrunerKind::Wanda,
        PrunerKind::SparseGpt,
        PrunerKind::SemiStructured { n: 2, m: 4 },
        PrunerKind::Structured,
        PrunerKind::Composite(CompositeOpts {
            use_obs: true,
            ..Default::default()
        }),
    ];
    let workers = [1usize, 2, 4, 8];
    let mut summary: Vec<Json> = Vec::new();
    for kind in &kinds {
        println!("\n— {} —", kind.name());
        header(&[
            "mode", "workers", "rank-ms", "prune-ms", "seal-ms",
            "wall-ms", "peak-KB",
        ]);
        let t = Instant::now();
        let want = sequential_reference(kind, &src, &pl, stats, hess);
        let seq_ms = t.elapsed().as_secs_f64() * 1e3;
        // sequential working set: the full dense clone it prunes
        println!(
            "{:>12}{:>12}{:>12}{:>12}{:>12}{:>12.1}{:>12.0}",
            "sequential", "-", "-", "-", "-", seq_ms,
            dense_bytes as f64 / 1024.0
        );
        summary.push(rec(&[
            ("kind", Json::str(kind.name())),
            ("mode", Json::str("sequential")),
            ("wall_ms", Json::num(seq_ms)),
            ("peak_bytes", Json::num(dense_bytes as f64)),
        ]));
        for &w in &workers {
            let rep = produce_with_snapshot(
                &src,
                &pl,
                Some(stats),
                Some(hess),
                &ProduceOpts::new(*kind).with_workers(w),
            );
            assert!(
                identical(&want, &rep.model),
                "{} at {w} workers diverged from sequential",
                kind.name()
            );
            assert!(
                rep.peak_resident_bytes < dense_bytes,
                "{} at {w} workers: peak {} !< dense {}",
                kind.name(),
                rep.peak_resident_bytes,
                dense_bytes
            );
            println!(
                "{:>12}{:>12}{:>12.1}{:>12.1}{:>12.1}{:>12.1}{:>12.0}",
                "pipeline",
                w,
                rep.rank_ms,
                rep.prune_ms,
                rep.seal_ms,
                rep.wall_ms,
                rep.peak_resident_bytes as f64 / 1024.0
            );
            summary.push(rec(&[
                ("kind", Json::str(kind.name())),
                ("mode", Json::str("pipeline")),
                ("workers", Json::num(w as f64)),
                ("rank_ms", Json::num(rep.rank_ms)),
                ("prune_ms", Json::num(rep.prune_ms)),
                ("seal_ms", Json::num(rep.seal_ms)),
                ("wall_ms", Json::num(rep.wall_ms)),
                ("peak_bytes", Json::num(rep.peak_resident_bytes as f64)),
                ("sealed_bytes", Json::num(rep.sealed_bytes as f64)),
                ("speedup_vs_seq", Json::num(seq_ms / rep.wall_ms.max(1e-9))),
            ]));
        }
    }

    // machine-readable perf-trajectory file (make bench-produce)
    let mut out = Json::obj();
    out.set("bench", Json::str("produce_speed"));
    out.set("layers", Json::num(layers as f64));
    out.set("d_model", Json::num(d_model as f64));
    out.set("p", Json::num(p));
    out.set("dense_bytes", Json::num(dense_bytes as f64));
    out.set("capture_ms", Json::num(capture_ms));
    out.set("rows", Json::Arr(summary.clone()));
    std::fs::write("BENCH_produce.json", out.to_string())?;
    println!("\n[wrote BENCH_produce.json]");

    for row in summary {
        b.row("rows", row);
    }
    b.finish();
    Ok(())
}
