//! Fig. 12 (appendix): perplexity and pruning time of the 80 %-pruned
//! LLaMa-3.1-8B proxy as the calibration set grows 1 → 256 samples.
//! Paper shape: PPL improves until ~128 samples then plateaus;
//! projection achieves lower PPL at every sample count (even beating
//! global@128 with only 64 samples); pruning time grows with samples.

use mosaic::bench_support::{header, rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::perplexity_native;
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig12_calibration",
                           "PPL + prune time vs calibration samples");
    let mo = Mosaic::load("tl31")?;
    let seq = mo.dense.cfg.ctx.min(64);
    let wt = mo.store.split("wikitext2s")?;
    let sweep: Vec<usize> = if Bench::fast() {
        vec![4, 32]
    } else {
        vec![1, 2, 4, 8, 16, 32, 64, 128, 256]
    };
    header(&["samples", "method", "ppl", "time-s"]);
    for &n in &sweep {
        for u in [Uniformity::Global, Uniformity::Layer,
                  Uniformity::Projection] {
            // fresh pipeline per count so profiling cost is attributed
            let mut mo_n = Mosaic::load(&mo.name)?;
            let t0 = std::time::Instant::now();
            let (m, _) =
                mo_n.prune(0.8, u, Category::Unstructured, n)?;
            let t = t0.elapsed().as_secs_f64();
            let ppl = perplexity_native(&m, &wt, seq, 16);
            println!("{:>12}{:>12}{:>12.2}{:>12.2}", n, u.name(), ppl, t);
            b.row("series", rec(&[
                ("samples", Json::num(n as f64)),
                ("method", Json::str(u.name())),
                ("ppl", Json::num(ppl)),
                ("prune_time_s", Json::num(t)),
            ]));
        }
    }
    let _ = &mo;
    b.finish();
    Ok(())
}
