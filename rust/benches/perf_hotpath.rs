//! §Perf: L3 hot-path microbenchmarks — matmul/matvec bandwidth, decode
//! throughput, and RC/PC stage timing. Used for the before/after log in
//! EXPERIMENTS.md §Perf and as the roofline anchor for the platform
//! simulator.

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::measure_native;
use mosaic::model::{DecodeState, decode_step};
use mosaic::tensor::{matmul, matvec, Tensor};
use mosaic::util::json::Json;
use mosaic::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("perf_hotpath", "L3 hot-path microbenches");
    let mut rng = Pcg32::seeded(1);

    // ---- matmul GFLOP/s across shapes
    for &(m, k, n) in
        &[(64usize, 64usize, 224usize), (256, 64, 224), (512, 512, 512)]
    {
        let x = Tensor::new((0..m * k).map(|_| rng.normal()).collect(),
                            vec![m, k]);
        let w = Tensor::new((0..k * n).map(|_| rng.normal()).collect(),
                            vec![k, n]);
        let reps = if m >= 512 { 20 } else { 200 };
        let t0 = std::time::Instant::now();
        let mut sink = 0f32;
        for _ in 0..reps {
            sink += matmul(&x, &w).data[0];
        }
        let s = t0.elapsed().as_secs_f64() / reps as f64;
        let gflops = 2.0 * (m * k * n) as f64 / s / 1e9;
        println!("matmul {m}x{k}x{n}: {gflops:.2} GFLOP/s (sink {sink:.1})");
        b.row("matmul", rec(&[
            ("shape", Json::str(&format!("{m}x{k}x{n}"))),
            ("gflops", Json::num(gflops)),
        ]));
    }

    // ---- matvec effective bandwidth (decode roofline)
    let (k, n) = (172usize, 4096usize);
    let w = Tensor::new((0..k * n).map(|_| rng.normal()).collect(),
                        vec![k, n]);
    let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let mut out = vec![0f32; n];
    let reps = 2000;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        matvec(&x, &w, &mut out);
    }
    let s = t0.elapsed().as_secs_f64() / reps as f64;
    let gbs = (k * n * 4) as f64 / s / 1e9;
    println!("matvec {k}x{n}: {gbs:.2} GB/s effective weight stream");
    b.set("matvec_gbs", Json::num(gbs));

    // ---- end-to-end decode throughput per model
    for name in ["tl1_7", "tl31"] {
        let mo = Mosaic::load(name)?;
        let m = &mo.dense;
        let mut st = DecodeState::new(m, 64);
        // warm
        for i in 0..8u16 {
            decode_step(m, &mut st, 3 + i);
        }
        st.reset();
        let t0 = std::time::Instant::now();
        let n_tok = 48;
        for i in 0..n_tok {
            decode_step(m, &mut st, 3 + (i % 40) as u16);
        }
        let s = t0.elapsed().as_secs_f64();
        let tps = n_tok as f64 / s;
        let wbytes = m.model_bytes() as f64;
        println!(
            "{name}: decode {tps:.0} tok/s ({:.2} GB/s weight stream)",
            tps * wbytes / 1e9
        );
        b.row("decode", rec(&[
            ("model", Json::str(name)),
            ("tok_per_s", Json::num(tps)),
            ("weight_gbs", Json::num(tps * wbytes / 1e9)),
        ]));
        let perf = measure_native(m, 32, 16, 3);
        b.row("generate", rec(&[
            ("model", Json::str(name)),
            ("latency_s", Json::num(perf.latency_s)),
            ("prefill_s", Json::num(perf.prefill_s)),
            ("decode_s", Json::num(perf.decode_s)),
        ]));
    }

    // ---- RC/PC stage timing
    let mut mo = Mosaic::load("tl1_7")?;
    let t0 = std::time::Instant::now();
    let _stats = mo.activation_stats(16)?;
    let profile_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let _r = mo.global_rank(mosaic::prune::Uniformity::Projection, 16)?;
    let rank_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let _ = mo.prune(0.6, mosaic::prune::Uniformity::Projection,
                     mosaic::prune::Category::Composite, 16)?;
    let prune_s = t0.elapsed().as_secs_f64();
    println!("RC profile {profile_s:.2}s, rank {rank_s:.2}s, \
              PC composite prune {prune_s:.2}s");
    b.set("rc_profile_s", Json::num(profile_s));
    b.set("rc_rank_s", Json::num(rank_s));
    b.set("pc_prune_s", Json::num(prune_s));
    b.finish();
    Ok(())
}
