//! §Perf: L3 hot-path microbenchmarks — matmul/matvec bandwidth, storage
//! backend (f32/f16/CSR/i8/i4/csr8) matvec + decode comparisons, and RC/PC stage
//! timing. Used for the before/after log in ARCHITECTURE.md §Perf and as
//! the roofline anchor for the platform simulator.
//!
//! The storage sections run without artifacts (random models), so the
//! backend trajectory is tracked on every host; the per-model sections
//! are skipped gracefully when `make artifacts` has not run.

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::measure_native;
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::{decode_step, DecodeState};
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::tensor::{matmul, matvec, matvec_storage, ProjStorage, Tensor};
use mosaic::util::json::Json;
use mosaic::util::rng::Pcg32;

/// Zero a deterministic `sparsity` fraction of a tensor by magnitude.
fn sparsify(t: &mut Tensor, sparsity: f64) {
    if sparsity <= 0.0 {
        return;
    }
    let sc = scores(t, None, Metric::Magnitude);
    mask_lowest(t, &sc, sparsity);
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("perf_hotpath", "L3 hot-path microbenches");
    let mut rng = Pcg32::seeded(1);

    // ---- matmul GFLOP/s across shapes
    for &(m, k, n) in
        &[(64usize, 64usize, 224usize), (256, 64, 224), (512, 512, 512)]
    {
        let x = Tensor::new((0..m * k).map(|_| rng.normal()).collect(),
                            vec![m, k]);
        let w = Tensor::new((0..k * n).map(|_| rng.normal()).collect(),
                            vec![k, n]);
        let reps = if m >= 512 { 20 } else { 200 };
        let t0 = std::time::Instant::now();
        let mut sink = 0f32;
        for _ in 0..reps {
            sink += matmul(&x, &w).data[0];
        }
        let s = t0.elapsed().as_secs_f64() / reps as f64;
        let gflops = 2.0 * (m * k * n) as f64 / s / 1e9;
        println!("matmul {m}x{k}x{n}: {gflops:.2} GFLOP/s (sink {sink:.1})");
        b.row("matmul", rec(&[
            ("shape", Json::str(&format!("{m}x{k}x{n}"))),
            ("gflops", Json::num(gflops)),
        ]));
    }

    // ---- matvec effective bandwidth (decode roofline)
    let (k, n) = (172usize, 4096usize);
    let w = Tensor::new((0..k * n).map(|_| rng.normal()).collect(),
                        vec![k, n]);
    let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let mut out = vec![0f32; n];
    let reps = 2000;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        matvec(&x, &w, &mut out);
    }
    let s = t0.elapsed().as_secs_f64() / reps as f64;
    let gbs = (k * n * 4) as f64 / s / 1e9;
    println!("matvec {k}x{n}: {gbs:.2} GB/s effective weight stream");
    b.set("matvec_gbs", Json::num(gbs));

    // ---- storage backends: dense-f32 vs f16/CSR/i8/i4/csr8 matvec
    //      across sparsity levels (the ISSUE-1 acceptance comparison;
    //      quantized rows added for ISSUE 9 — quant_speed.rs has the
    //      full parity-checked sweep). The
    //      matrix is sized past L2 so the stream cost, not the loop
    //      overhead, dominates — as in a real lm_head/ffn projection.
    {
        let (k, n) = (1024usize, 4096usize);
        let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
        println!("\n— storage backends, matvec {k}x{n} —");
        for &sp in &[0.0f64, 0.5, 0.7, 0.9] {
            let mut w = Tensor::new(
                (0..k * n).map(|_| rng.normal()).collect(),
                vec![k, n],
            );
            sparsify(&mut w, sp);
            let backends = [
                ("f32", ProjStorage::from_dense(w.clone())),
                ("f16", ProjStorage::seal_f16(&w)),
                ("csr", ProjStorage::seal_csr(&w)),
                ("i8", ProjStorage::seal_i8(&w, 128)),
                ("i4", ProjStorage::seal_i4(&w, 128)),
                ("csr8", ProjStorage::seal_csr_i8(&w, 128)),
            ];
            let mut f32_us = 0.0f64;
            for (name, s) in backends.iter() {
                let mut out = vec![0f32; n];
                // warm
                for _ in 0..3 {
                    matvec_storage(&x, s, &mut out);
                }
                let reps = 60;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    matvec_storage(&x, s, &mut out);
                }
                let us = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
                if *name == "f32" {
                    f32_us = us;
                }
                let speedup = if us > 0.0 { f32_us / us } else { 0.0 };
                println!(
                    "  sparsity {sp:.1} {name}: {us:8.1} µs \
                     ({speedup:4.2}x vs f32, {} KB resident)",
                    s.resident_bytes() / 1024
                );
                b.row("storage_matvec", rec(&[
                    ("sparsity", Json::num(sp)),
                    ("backend", Json::str(name)),
                    ("us", Json::num(us)),
                    ("speedup_vs_f32", Json::num(speedup)),
                    ("resident_bytes",
                     Json::num(s.resident_bytes() as f64)),
                ]));
            }
        }
    }

    // ---- storage backends, end-to-end decode: 70 %-unstructured-pruned
    //      random model, dense working copies vs compact()ed storage
    {
        let mk = || {
            let mut m = random_model_sized(9, 4, 256, 8, 704, 512, 128);
            for l in m.layers.iter_mut() {
                for s in l.projs.iter_mut() {
                    sparsify(s.dense_mut(), 0.7);
                }
            }
            m
        };
        let dense = mk();
        let mut sealed = mk();
        sealed.compact();
        println!("\n— storage backends, decode (70% unstructured) —");
        let mut dense_tps = 0.0f64;
        for (name, m) in [("dense-f32", &dense), ("compact", &sealed)] {
            let mut st = DecodeState::new(m, 64);
            for i in 0..4u16 {
                decode_step(m, &mut st, 3 + i);
            }
            st.reset();
            let n_tok = 24;
            let t0 = std::time::Instant::now();
            for i in 0..n_tok {
                decode_step(m, &mut st, 3 + (i % 40) as u16);
            }
            let tps = n_tok as f64 / t0.elapsed().as_secs_f64();
            if name == "dense-f32" {
                dense_tps = tps;
            }
            println!(
                "  {name}: {tps:.1} tok/s ({:.2}x, resident {} KB)",
                tps / dense_tps.max(1e-9),
                m.resident_bytes() / 1024
            );
            b.row("storage_decode", rec(&[
                ("variant", Json::str(name)),
                ("tok_per_s", Json::num(tps)),
                ("speedup_vs_dense", Json::num(tps / dense_tps.max(1e-9))),
                ("resident_bytes", Json::num(m.resident_bytes() as f64)),
                ("model_bytes", Json::num(m.model_bytes() as f64)),
            ]));
        }
    }

    // ---- end-to-end decode throughput per model (needs artifacts)
    for name in ["tl1_7", "tl31"] {
        let mo = match Mosaic::load(name) {
            Ok(mo) => mo,
            Err(e) => {
                println!("skipping {name}: {e}");
                continue;
            }
        };
        let m = &mo.dense;
        let mut st = DecodeState::new(m, 64);
        // warm
        for i in 0..8u16 {
            decode_step(m, &mut st, 3 + i);
        }
        st.reset();
        let t0 = std::time::Instant::now();
        let n_tok = 48;
        for i in 0..n_tok {
            decode_step(m, &mut st, 3 + (i % 40) as u16);
        }
        let s = t0.elapsed().as_secs_f64();
        let tps = n_tok as f64 / s;
        let wbytes = m.model_bytes() as f64;
        println!(
            "{name}: decode {tps:.0} tok/s ({:.2} GB/s weight stream)",
            tps * wbytes / 1e9
        );
        b.row("decode", rec(&[
            ("model", Json::str(name)),
            ("tok_per_s", Json::num(tps)),
            ("weight_gbs", Json::num(tps * wbytes / 1e9)),
        ]));
        let perf = measure_native(m, 32, 16, 3);
        b.row("generate", rec(&[
            ("model", Json::str(name)),
            ("latency_s", Json::num(perf.latency_s)),
            ("prefill_s", Json::num(perf.prefill_s)),
            ("decode_s", Json::num(perf.decode_s)),
            ("resident_bytes", Json::num(perf.resident_bytes as f64)),
        ]));
    }

    // ---- RC/PC stage timing (needs artifacts)
    match Mosaic::load("tl1_7") {
        Ok(mut mo) => {
            let t0 = std::time::Instant::now();
            let _stats = mo.activation_stats(16)?;
            let profile_s = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            let _r =
                mo.global_rank(mosaic::prune::Uniformity::Projection, 16)?;
            let rank_s = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            let _ = mo.prune(0.6, mosaic::prune::Uniformity::Projection,
                             mosaic::prune::Category::Composite, 16)?;
            let prune_s = t0.elapsed().as_secs_f64();
            println!("RC profile {profile_s:.2}s, rank {rank_s:.2}s, \
                      PC composite prune {prune_s:.2}s");
            b.set("rc_profile_s", Json::num(profile_s));
            b.set("rc_rank_s", Json::num(rank_s));
            b.set("pc_prune_s", Json::num(prune_s));
        }
        Err(e) => println!("skipping RC/PC timing: {e}"),
    }
    b.finish();
    Ok(())
}
