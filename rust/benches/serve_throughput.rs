//! Serving-layer throughput/latency: dense vs composite-pruned SLMs
//! under the same Poisson trace, plus batch-width scaling through the
//! batched decode path (one weight pass per projection per step). This
//! is the deployment-side measurement behind the paper's "up to 67 %
//! faster inference" once the SLM is actually serving requests.
//!
//! The batch-width sweep is artifact-free (random 70 %-pruned model,
//! dense working copies vs `compact()`ed storage) and must show
//! per-step cost growing **sublinearly** from width 1 → 8: the weights
//! are streamed once per step however many sequences share it. The
//! model-variant section needs artifacts and is skipped without them.
//!
//! Emits `BENCH_serve.json` (tokens/s, mean occupancy, resident bytes
//! per row) for cross-PR perf tracking — run via `make bench-serve`.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use mosaic::bench_support::{header, rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::data::trace::{generate, percentiles, Arrival, TraceConfig};
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::prune::{Category, Uniformity};
use mosaic::serve::{
    wait_reply, ModelRegistry, ServeConfig, Server, SubmitSpec,
};
use mosaic::util::json::Json;

struct DriveOut {
    tok_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    occupancy: f64,
    /// mean wall time per batched engine step
    step_us: f64,
}

fn drive(server: &Server, trace: &[mosaic::data::trace::TraceItem])
         -> DriveOut {
    drive_model(server, None, trace)
}

/// Replay `trace` against one registered model (None = the default);
/// per-step stats come from that model's engine.
fn drive_model(
    server: &Server,
    model: Option<&str>,
    trace: &[mosaic::data::trace::TraceItem],
) -> DriveOut {
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for item in trace {
        if let Some(sleep) =
            Duration::from_secs_f64(item.at_s).checked_sub(t0.elapsed())
        {
            std::thread::sleep(sleep);
        }
        let sent = Instant::now();
        let spec = SubmitSpec {
            model: model.map(String::from),
            ..SubmitSpec::greedy(&item.prompt, item.max_new)
        };
        if let Ok(rx) = server.submit_spec(spec) {
            pending.push((sent, rx));
        }
    }
    let mut lat = Vec::new();
    let mut tokens = 0usize;
    for (sent, rx) in pending {
        if let Ok(r) = wait_reply(&rx, Duration::from_secs(60)) {
            lat.push(sent.elapsed().as_secs_f64() * 1e3);
            tokens += r.tokens.len();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = match model {
        None => server.stats.clone(),
        Some(name) => server.model_stats(name).expect("registered"),
    };
    let steps = stats.batch_steps.load(Ordering::Relaxed);
    let step_us = stats.step_wall_us.load(Ordering::Relaxed) as f64
        / steps.max(1) as f64;
    let (p50, p95, _) = percentiles(lat);
    DriveOut {
        tok_per_s: tokens as f64 / wall,
        p50_ms: p50,
        p95_ms: p95,
        occupancy: stats.mean_occupancy(),
        // engine-side wall per decode-carrying batch pass (excludes
        // queue/idle time — the sublinear-growth signal)
        step_us,
    }
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("serve_throughput",
                           "continuous-batching serving perf");
    let samples = Bench::samples();
    let n_requests = if Bench::fast() { 16 } else { 48 };
    // closed-loop saturation: all requests arrive at t=0 so tok/s
    // reflects engine speed, not the arrival process
    let trace = generate(&TraceConfig {
        arrival: Arrival::Batch,
        rate: 150.0,
        n_requests,
        prompt_len_mean: 12,
        prompt_len_max: 24,
        max_new: 16,
        ..Default::default()
    });
    // rows mirrored into BENCH_serve.json for cross-PR tracking
    let mut summary: Vec<Json> = Vec::new();

    // ---- model variants (needs artifacts)
    match Mosaic::load("tl1_7") {
        Ok(mut mo) => {
            println!("{}", "— model variants (batch width 6) —");
            header(&["variant", "tok/s", "p50-ms", "p95-ms", "res-KB"]);
            // sealed variants run the engine directly on f16/CSR
            // storage — an unstructured-pruned model serving both
            // smaller and faster than its dense working copy
            let unstructured70 =
                mo.prune_wanda(0.7, Uniformity::Projection, samples)?;
            let mut unstructured70_sealed = unstructured70.clone();
            unstructured70_sealed.compact();
            let composite60 = mo
                .prune(0.6, Uniformity::Projection, Category::Composite,
                       samples)?
                .0;
            let mut composite60_sealed = composite60.clone();
            composite60_sealed.compact();
            let variants: Vec<(&str, mosaic::model::ModelWeights)> = vec![
                ("dense", mo.dense.clone()),
                ("unstr70", unstructured70),
                ("unstr70-seal", unstructured70_sealed),
                ("composite60", composite60),
                ("comp60-seal", composite60_sealed),
                ("structured60",
                 mo.prune(0.6, Uniformity::Projection,
                          Category::Structured, samples)?.0),
            ];
            for (name, model) in variants {
                let resident = model.resident_bytes();
                let srv = Server::start(
                    model,
                    ServeConfig {
                        max_batch: 6,
                        max_queue: 256,
                        ..Default::default()
                    },
                    0,
                )?;
                let d = drive(&srv, &trace);
                println!(
                    "{name:>12}{:>12.0}{:>12.2}{:>12.2}{:>12}",
                    d.tok_per_s, d.p50_ms, d.p95_ms, resident / 1024
                );
                let row = rec(&[
                    ("section", Json::str("variants")),
                    ("variant", Json::str(name)),
                    ("tok_per_s", Json::num(d.tok_per_s)),
                    ("p50_ms", Json::num(d.p50_ms)),
                    ("p95_ms", Json::num(d.p95_ms)),
                    ("resident_bytes", Json::num(resident as f64)),
                    ("occupancy", Json::num(d.occupancy)),
                ]);
                b.row("variants", row.clone());
                summary.push(row);
                srv.shutdown();
            }
        }
        Err(e) => println!("skipping model-variant rows: {e}"),
    }

    // ---- batch-width sweep (artifact-free): 70 %-pruned random
    // model, dense working copies vs compact()ed storage. Sublinear
    // per-step cost from width 1 → 8 is the one-weight-pass invariant
    // showing up on the wall clock.
    let mk = || {
        let mut m = random_model_sized(9, 4, 256, 8, 704, 512, 128);
        for l in m.layers.iter_mut() {
            for s in l.projs.iter_mut() {
                let t = s.dense_mut();
                let sc = scores(t, None, Metric::Magnitude);
                mask_lowest(t, &sc, 0.7);
            }
        }
        m
    };
    let dense = mk();
    let mut sealed = dense.clone();
    sealed.compact();
    let widths: &[usize] =
        if Bench::fast() { &[1, 4] } else { &[1, 2, 4, 8] };
    println!("\n— batch-width sweep (70% pruned, dense vs sealed) —");
    header(&["variant", "width", "tok/s", "p95-ms", "step-us", "occ"]);
    for (vname, model) in [("dense", &dense), ("sealed", &sealed)] {
        let resident = model.resident_bytes();
        for &w in widths {
            let srv = Server::start(
                model.clone(),
                ServeConfig {
                    max_batch: w,
                    max_queue: 256,
                    ..Default::default()
                },
                0,
            )?;
            let d = drive(&srv, &trace);
            println!(
                "{vname:>12}{w:>12}{:>12.0}{:>12.2}{:>12.0}{:>12.2}",
                d.tok_per_s, d.p95_ms, d.step_us, d.occupancy
            );
            let row = rec(&[
                ("section", Json::str("widths")),
                ("variant", Json::str(vname)),
                ("width", Json::num(w as f64)),
                ("tok_per_s", Json::num(d.tok_per_s)),
                ("p95_ms", Json::num(d.p95_ms)),
                ("step_us", Json::num(d.step_us)),
                ("occupancy", Json::num(d.occupancy)),
                ("resident_bytes", Json::num(resident as f64)),
            ]);
            b.row("widths", row.clone());
            summary.push(row);
            srv.shutdown();
        }
    }

    // ---- registry (artifact-free): dense and a sealed 70 %-pruned
    // variant served from ONE process, routed per request — the
    // family-serving deployment story, with resident bytes per model
    println!("\n— registry: dense + sealed from one process —");
    header(&["model", "tok/s", "p95-ms", "res-KB", "occ"]);
    {
        // unmasked twin of the sweep model: truly dense weights next
        // to the sealed 70 %-pruned variant
        let dense_unmasked =
            random_model_sized(9, 4, 256, 8, 704, 512, 128);
        let mut reg = ModelRegistry::new();
        reg.register("dense", dense_unmasked)?;
        reg.register("comp70-seal", sealed.clone())?;
        let srv = Server::start_registry(
            reg,
            ServeConfig {
                max_batch: 6,
                max_queue: 256,
                ..Default::default()
            },
            0,
        )?;
        let residents: Vec<(String, usize)> = srv
            .models()
            .iter()
            .map(|mi| (mi.name.clone(), mi.resident_bytes))
            .collect();
        for (mname, resident) in residents {
            let d = drive_model(&srv, Some(&mname), &trace);
            println!(
                "{mname:>12}{:>12.0}{:>12.2}{:>12}{:>12.2}",
                d.tok_per_s,
                d.p95_ms,
                resident / 1024,
                d.occupancy
            );
            let row = rec(&[
                ("section", Json::str("registry")),
                ("model", Json::str(&mname)),
                ("tok_per_s", Json::num(d.tok_per_s)),
                ("p95_ms", Json::num(d.p95_ms)),
                ("resident_bytes", Json::num(resident as f64)),
                ("occupancy", Json::num(d.occupancy)),
            ]);
            b.row("registry", row.clone());
            summary.push(row);
        }
        srv.shutdown();
    }

    // machine-readable perf-trajectory file (make bench-serve)
    let mut out = Json::obj();
    out.set("bench", Json::str("serve_throughput"));
    out.set("n_requests", Json::num(n_requests as f64));
    out.set("rows", Json::Arr(summary));
    std::fs::write("BENCH_serve.json", out.to_string())?;
    println!("[wrote BENCH_serve.json]");

    b.finish();
    Ok(())
}
