//! Serving-layer throughput/latency: dense vs composite-pruned SLMs
//! under the same Poisson trace, plus batch-width scaling. This is the
//! deployment-side measurement behind the paper's "up to 67 % faster
//! inference" once the SLM is actually serving requests.

use std::time::{Duration, Instant};

use mosaic::bench_support::{header, rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::data::trace::{generate, percentiles, Arrival, TraceConfig};
use mosaic::prune::{Category, Uniformity};
use mosaic::serve::{ServeConfig, Server};
use mosaic::util::json::Json;

fn drive(server: &Server, trace: &[mosaic::data::trace::TraceItem])
         -> (f64, f64, f64) {
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for item in trace {
        if let Some(sleep) =
            Duration::from_secs_f64(item.at_s).checked_sub(t0.elapsed())
        {
            std::thread::sleep(sleep);
        }
        let sent = Instant::now();
        if let Ok(rx) = server.submit(item.prompt.clone(), item.max_new) {
            pending.push((sent, rx));
        }
    }
    let mut lat = Vec::new();
    let mut tokens = 0usize;
    for (sent, rx) in pending {
        if let Ok(r) = rx.recv_timeout(Duration::from_secs(60)) {
            lat.push(sent.elapsed().as_secs_f64() * 1e3);
            tokens += r.tokens.len();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (p50, p95, _) = percentiles(lat);
    (tokens as f64 / wall, p50, p95)
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("serve_throughput",
                           "continuous-batching serving perf");
    let mut mo = Mosaic::load("tl1_7")?;
    let samples = Bench::samples();
    let n_requests = if Bench::fast() { 16 } else { 48 };
    // closed-loop saturation: all requests arrive at t=0 so tok/s
    // reflects engine speed, not the arrival process
    let trace = generate(&TraceConfig {
        arrival: Arrival::Batch,
        rate: 150.0,
        n_requests,
        prompt_len_mean: 12,
        prompt_len_max: 24,
        max_new: 16,
        ..Default::default()
    });

    println!("{}", "— model variants (batch width 6) —");
    header(&["variant", "tok/s", "p50-ms", "p95-ms", "res-KB"]);
    // sealed variants run the engine directly on f16/CSR storage — the
    // first time an unstructured-pruned model serves both smaller and
    // faster than its dense working copy
    let unstructured70 = mo.prune_wanda(0.7, Uniformity::Projection,
                                        samples)?;
    let mut unstructured70_sealed = unstructured70.clone();
    unstructured70_sealed.compact();
    let composite60 =
        mo.prune(0.6, Uniformity::Projection, Category::Composite,
                 samples)?.0;
    let mut composite60_sealed = composite60.clone();
    composite60_sealed.compact();
    let variants: Vec<(&str, mosaic::model::ModelWeights)> = vec![
        ("dense", mo.dense.clone()),
        ("unstr70", unstructured70),
        ("unstr70-seal", unstructured70_sealed),
        ("composite60", composite60),
        ("comp60-seal", composite60_sealed),
        ("structured60",
         mo.prune(0.6, Uniformity::Projection, Category::Structured,
                  samples)?.0),
    ];
    for (name, model) in variants {
        let resident = model.resident_bytes();
        let srv = Server::start(
            model, ServeConfig { max_batch: 6, max_queue: 256, ..Default::default() }, 0)?;
        let (tps, p50, p95) = drive(&srv, &trace);
        println!("{name:>12}{tps:>12.0}{p50:>12.2}{p95:>12.2}{:>12}",
                 resident / 1024);
        b.row("variants", rec(&[
            ("variant", Json::str(name)),
            ("tok_per_s", Json::num(tps)),
            ("p50_ms", Json::num(p50)),
            ("p95_ms", Json::num(p95)),
            ("resident_bytes", Json::num(resident as f64)),
            ("occupancy", Json::num(srv.stats.mean_occupancy())),
        ]));
        srv.shutdown();
    }

    println!("\n— batch-width scaling (composite60) —");
    header(&["width", "tok/s", "p95-ms"]);
    let (pruned, _) = mo.prune(0.6, Uniformity::Projection,
                               Category::Composite, samples)?;
    let widths: &[usize] = if Bench::fast() { &[4] } else { &[1, 2, 4, 8] };
    for &w in widths {
        let srv = Server::start(
            pruned.clone(),
            ServeConfig { max_batch: w, max_queue: 256, ..Default::default() }, 0)?;
        let (tps, _p50, p95) = drive(&srv, &trace);
        println!("{w:>12}{tps:>12.0}{p95:>12.2}");
        b.row("widths", rec(&[
            ("width", Json::num(w as f64)),
            ("tok_per_s", Json::num(tps)),
            ("p95_ms", Json::num(p95)),
        ]));
        srv.shutdown();
    }
    b.finish();
    Ok(())
}
