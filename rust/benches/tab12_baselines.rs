//! Table XII: LLaMa-7B proxy pruned by 70 % — zero-shot accuracy of
//! Magnitude / Wanda / SparseGPT / OWL / Mosaic on all seven tasks.
//! Paper shape: Magnitude < Wanda < SparseGPT < OWL < Mosaic.

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::{mean_accuracy, per_task_accuracy};
use mosaic::prune::{self, plan, Category, Metric, Uniformity};
use mosaic::rank::GlobalRank;
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("tab12_baselines",
                           "pruning-method shoot-out @70%");
    let mut mo = Mosaic::load("tl1_7")?;
    // paper's setting: LLaMa-7B at 70 % (note: our synthetic tasks are
    // easier than the paper's suite, so absolute gaps compress — see
    // ARCHITECTURE.md §Benches TAB12 discussion)
    let p = 0.7;
    let samples = Bench::samples();
    let stats = mo.activation_stats(samples)?;
    let uniform = GlobalRank {
        rank: vec![vec![1.0; 7]; mo.dense.cfg.n_layers],
        alpha: 5.0,
    };
    let hess = mo.hessians(samples)?.clone_shallow();

    let mut variants: Vec<(&str, mosaic::model::ModelWeights)> = Vec::new();
    let gplan = plan(&uniform, p, Uniformity::Global);
    let mut m = mo.dense.clone();
    prune::prune_unstructured(&mut m, &gplan, None, Metric::Magnitude);
    variants.push(("Magnitude", m));
    let mut m = mo.dense.clone();
    prune::prune_unstructured(&mut m, &gplan, Some(&stats), Metric::Wanda);
    variants.push(("Wanda", m));
    let mut m = mo.dense.clone();
    prune::sparsegpt::prune_sparsegpt(&mut m, &gplan, &hess);
    variants.push(("SparseGPT", m));
    let (m, _) = mo.prune(p, Uniformity::Layer, Category::Unstructured,
                          samples)?;
    variants.push(("OWL", m));
    let (m, _) = mo.prune(p, Uniformity::Projection,
                          Category::Unstructured, samples)?;
    variants.push(("Mosaic", m));

    let dense_tasks = per_task_accuracy(&mo.dense, &mo.store)?;
    print!("{:<10}", "method");
    for (t, _) in &dense_tasks {
        print!(" {:>7}", &t[..t.len().min(7)]);
    }
    println!(" {:>7}", "mean");
    let print_row = |name: &str, m: &mosaic::model::ModelWeights,
                         b: &mut Bench| -> anyhow::Result<f64> {
        let per = per_task_accuracy(m, &mo.store)?;
        print!("{name:<10}");
        let mut tasks = Json::obj();
        for (t, a) in &per {
            print!(" {:>7.1}", a);
            tasks.set(t, Json::num(*a));
        }
        let mean = mean_accuracy(m, &mo.store)?;
        println!(" {:>7.1}", mean);
        b.row("series", rec(&[
            ("method", Json::str(name)),
            ("mean_acc", Json::num(mean)),
            ("per_task", tasks),
        ]));
        Ok(mean)
    };
    let dense_clone = mo.dense.clone();
    print_row("dense", &dense_clone, &mut b)?;
    for (name, m) in &variants {
        print_row(name, m, &mut b)?;
    }
    b.finish();
    Ok(())
}
