//! Ablations over the design choices ARCHITECTURE.md calls out:
//!   A1 — POD outlier threshold α (Eq. 6; paper: "typically five")
//!   A2 — composite structural share σ (our split of the p budget)
//!   A3 — planner spreads γ_L/γ_P (the non-uniformity strength)
//!   A4 — 2:4 semi-structured vs unstructured 50 % (the CUTLASS format)

use mosaic::bench_support::{header, rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::{measure_native, perplexity_native};
use mosaic::prune::composite::CompositeOpts;
use mosaic::prune::{self, plan, Metric, Uniformity};
use mosaic::rank::compute_global_rank;
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("ablate_design", "design-choice ablations");
    let mut mo = Mosaic::load("tl1_7")?;
    let samples = Bench::samples();
    let stats = mo.activation_stats(samples)?;
    let seq = mo.dense.cfg.ctx.min(64);
    let wt = mo.store.split("wikitext2s")?;
    let p = 0.8;

    // ---- A1: alpha sweep (rank changes -> plan changes -> PPL)
    println!("\nA1: POD alpha sweep (p={p}, projection+wanda)");
    header(&["alpha", "ppl"]);
    let alphas: &[f64] =
        if Bench::fast() { &[5.0] } else { &[2.0, 3.0, 5.0, 8.0, 12.0] };
    for &alpha in alphas {
        let dense = mo.dense.clone();
        let rank = compute_global_rank(&dense, &stats, alpha, None)?;
        let pl = plan(&rank, p, Uniformity::Projection);
        let mut m = mo.dense.clone();
        prune::prune_unstructured(&mut m, &pl, Some(&stats), Metric::Wanda);
        let ppl = perplexity_native(&m, &wt, seq, 16);
        mosaic::bench_support::rowf(&[alpha, ppl]);
        b.row("alpha", rec(&[("alpha", Json::num(alpha)),
                             ("ppl", Json::num(ppl))]));
    }

    // ---- A2: composite structural share sweep
    println!("\nA2: composite structural share sweep (p={p})");
    header(&["share", "ppl", "bytes", "latency"]);
    let shares: &[f64] =
        if Bench::fast() { &[0.5] } else { &[0.0, 0.25, 0.5, 0.75, 1.0] };
    let rank = mo.global_rank(Uniformity::Projection, samples)?;
    let hess = mo.hessians(samples)?.clone_shallow();
    for &share in shares {
        let pl = plan(&rank, p, Uniformity::Projection);
        let mut m = mo.dense.clone();
        prune::prune_composite(
            &mut m, &pl, Some(&stats), Some(&hess),
            CompositeOpts { struct_share: share, use_obs: true });
        let ppl = perplexity_native(&m, &wt, seq, 16);
        let perf = measure_native(&m, 32, 8, 2);
        mosaic::bench_support::rowf(&[
            share, ppl, m.model_bytes() as f64, perf.latency_s]);
        b.row("share", rec(&[
            ("share", Json::num(share)),
            ("ppl", Json::num(ppl)),
            ("bytes", Json::num(m.model_bytes() as f64)),
            ("latency_s", Json::num(perf.latency_s)),
        ]));
    }

    // ---- A3: planner spread strength (scale both gammas)
    println!("\nA3: planner spread scale (1.0 = shipped calibration)");
    header(&["scale", "ppl"]);
    let scales: &[f64] =
        if Bench::fast() { &[1.0] } else { &[0.0, 0.5, 1.0, 1.5, 2.0] };
    for &scale in scales {
        // emulate by interpolating between uniform and the planned targets
        let pl = plan(&rank, p, Uniformity::Projection);
        let mut pl2 = pl.clone();
        for t in pl2.targets.iter_mut().flatten() {
            *t = (p + (*t - p) * scale).clamp(0.0, 0.95);
        }
        let mut m = mo.dense.clone();
        prune::prune_unstructured(&mut m, &pl2, Some(&stats),
                                  Metric::Wanda);
        let ppl = perplexity_native(&m, &wt, seq, 16);
        mosaic::bench_support::rowf(&[scale, ppl]);
        b.row("spread", rec(&[("scale", Json::num(scale)),
                              ("ppl", Json::num(ppl))]));
    }

    // ---- A4: 2:4 semi-structured vs unstructured at 50 %
    println!("\nA4: 2:4 vs unstructured 50%");
    header(&["variant", "ppl"]);
    let mut m24 = mo.dense.clone();
    prune::semistructured::prune_nm(&mut m24, Some(&stats), 2, 4);
    let ppl24 = perplexity_native(&m24, &wt, seq, 16);
    let m50 = mo.prune_wanda(0.5, Uniformity::Global, samples)?;
    let ppl50 = perplexity_native(&m50, &wt, seq, 16);
    println!("{:>12}{:>12.2}", "2:4", ppl24);
    println!("{:>12}{:>12.2}", "unstr-50%", ppl50);
    b.set("nm_2_4_ppl", Json::num(ppl24));
    b.set("unstructured_50_ppl", Json::num(ppl50));

    b.finish();
    Ok(())
}
