//! Fig. 2: GPU memory and inference time of the 7B/13B proxies (dense
//! vs 50 % pruned) as the input grows 128 → 4096 tokens.
//! Paper shape: memory grows ~t² past the model size; latency grows
//! super-linearly; the pruned model is ~2x smaller and ~40 % faster.

use mosaic::bench_support::{header, rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::measure_native;
use mosaic::platform::{self, memory_required, ModelProfile, Workload};
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig2_tokens",
                           "memory/latency vs input tokens");
    let p1 = platform::by_name("P1").unwrap();
    let configs = [
        ("LLaMa-2-7B", 6.74e9, 32usize, 4096usize, 32usize),
        ("LLaMa-2-13B", 13.02e9, 40, 5120, 40),
    ];
    let token_sweep: &[usize] = if Bench::fast() {
        &[128, 4096]
    } else {
        &[128, 256, 512, 1024, 2048, 4096]
    };
    for (name, params, layers, d, heads) in configs {
        println!("\n-- {name} --");
        header(&["tokens", "dense-GB", "50%-GB", "dense-s", "50%-s"]);
        for &t in token_sweep {
            let w = Workload { tokens_in: t, tokens_out: 0, batch: 1 };
            let dense = ModelProfile::paper_scale(params, layers, d, heads);
            let mut half = dense;
            half.bytes /= 2;
            half.live_params /= 2;
            let md = memory_required(&dense, &w) as f64 / (1u64 << 30) as f64;
            let mh = memory_required(&half, &w) as f64 / (1u64 << 30) as f64;
            let ld = platform::simulate(&p1, &dense, &w).latency_s;
            let lh = platform::simulate(&p1, &half, &w).latency_s;
            mosaic::bench_support::rowf(&[t as f64, md, mh, ld, lh]);
            b.row("series", rec(&[
                ("model", Json::str(name)),
                ("tokens", Json::num(t as f64)),
                ("dense_gb", Json::num(md)),
                ("pruned_gb", Json::num(mh)),
                ("dense_s", Json::num(ld)),
                ("pruned_s", Json::num(lh)),
            ]));
        }
    }

    // host-measured anchor: tiny model, dense vs 50 % composite
    let mut mo = Mosaic::load("tl1_7")?;
    let (pruned, _) = mo.prune(0.5, Uniformity::Projection,
                               Category::Composite, Bench::samples())?;
    println!("\n-- host anchor (tl1_7, prefill+decode 8) --");
    header(&["tokens", "dense-s", "50%-s"]);
    for &t in &[8usize, 16, 24] {
        let d = measure_native(&mo.dense, t, 8, 3);
        let p = measure_native(&pruned, t, 8, 3);
        mosaic::bench_support::rowf(&[t as f64, d.latency_s, p.latency_s]);
        b.row("host", rec(&[
            ("tokens", Json::num(t as f64)),
            ("dense_s", Json::num(d.latency_s)),
            ("pruned_s", Json::num(p.latency_s)),
        ]));
    }
    b.finish();
    Ok(())
}
