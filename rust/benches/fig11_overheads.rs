//! Fig. 11: end-to-end overhead — pruning time plus the fine-tuning
//! time needed to reach a common quality bar, per pruning method.
//! Paper shape: projection pruning costs slightly more up front (weight
//! metrics per projection) but reaches the quality bar several times
//! faster, so its end-to-end bar is the shortest (up to 7.19x).
//!
//! The quality bar is the *global* method's eval loss after its full
//! fine-tuning run (the paper fine-tunes layer/projection models "to
//! match the same accuracy achieved by global pruning").

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::finetune::{train_lora, LoraConfig};
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig11_overheads",
                           "end-to-end prune+finetune overhead");
    let models: &[&str] =
        if Bench::fast() { &["tl31"] } else { &["tl31", "tl2_13"] };
    let full_steps = if Bench::fast() { 16 } else { 80 };
    let samples = Bench::samples();
    for name in models {
        let mut mo = Mosaic::load(name)?;
        let (rows, n_rows, seq) = mo.finetune_rows()?;
        println!("\n-- {name} (p=0.8) --");

        // pass 1: global's full run defines the quality bar
        let mut results = Vec::new();
        let mut bar = f64::MAX;
        for u in [Uniformity::Global, Uniformity::Layer,
                  Uniformity::Projection] {
            let t0 = std::time::Instant::now();
            let (pruned, _) =
                mo.prune(0.8, u, Category::Unstructured, samples)?;
            // prune overhead includes rank+profile attribution
            let prune_s = t0.elapsed().as_secs_f64();
            let cfg = LoraConfig {
                steps: full_steps,
                eval_every: 4,
                ..Default::default()
            };
            let rt = mo.runtime()?;
            rt.set_weights(&pruned)?;
            let res = train_lora(rt, &rows, n_rows, seq, &cfg)?;
            if u == Uniformity::Global {
                bar = res.eval_curve.last().unwrap().1;
            }
            results.push((u, prune_s, res));
        }
        println!("quality bar (global final eval loss): {bar:.4}");
        for (u, prune_s, res) in &results {
            // fine-tune time to reach the bar: first eval step ≤ bar
            let total_steps = res.train_curve.len().max(1);
            let hit = res
                .eval_curve
                .iter()
                .find(|(_, l)| *l <= bar * 1.002)
                .map(|(s, _)| *s + 1)
                .unwrap_or(total_steps);
            let ft_s = res.wall_s * hit as f64 / total_steps as f64;
            let total = prune_s + ft_s;
            println!(
                "{:>11}: prune {prune_s:>7.2}s + finetune-to-bar \
                 {ft_s:>7.2}s ({hit} steps) = {total:>7.2}s",
                u.name()
            );
            b.row("series", rec(&[
                ("model", Json::str(name)),
                ("method", Json::str(u.name())),
                ("prune_s", Json::num(*prune_s)),
                ("finetune_s", Json::num(ft_s)),
                ("steps_to_bar", Json::num(hit as f64)),
                ("total_s", Json::num(total)),
            ]));
        }
    }
    b.finish();
    Ok(())
}
