//! Paged-KV admission capacity: how many sequences fit a FIXED page
//! budget when admission accounts worst-case slabs vs observed
//! residency vs observed residency + prefix reuse of a shared system
//! prompt. Same pool, same workload, three admission policies:
//!
//! * `slab`  — reserve `max_ctx` rows per sequence up-front (the old
//!   per-sequence slab accounting);
//! * `paged` — reserve only `prompt + max_new` (observed need);
//! * `paged+prefix` — observed need minus the cached shared head.
//!
//! Every admitted sequence then actually runs (chunked prefill +
//! greedy decode), and decoded tokens are parity-checked across modes
//! — capacity gains that changed a single token would be bugs, not
//! wins. The prefix mode additionally asserts the shared head costs
//! ZERO weight passes at prefill (one tail chunk per sequence only).
//!
//! Emits `BENCH_kv.json` via `make bench-kv` for cross-PR tracking.
//! Artifact-free: runs on random weights anywhere.

use std::time::Instant;

use mosaic::bench_support::{header, rec, Bench};
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::{
    prefill_into, DecodeBatch, KvConfig, ModelWeights, KV_PAGE,
    PREFILL_CHUNK,
};
use mosaic::tensor::storage::weight_passes;
use mosaic::util::json::Json;

const MAX_CTX: usize = 256;
const MAX_BATCH: usize = 32;
const BUDGET_PAGES: usize = 32; // 1024 positions — 1/8 of worst case
const HEAD_LEN: usize = 2 * KV_PAGE; // shared system prompt, page-aligned
const TAIL_LEN: usize = 8; // per-request distinct suffix

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Slab,
    Paged,
    PagedPrefix,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Slab => "slab",
            Mode::Paged => "paged",
            Mode::PagedPrefix => "paged+prefix",
        }
    }
}

struct ModeOut {
    admitted: usize,
    /// decoded tokens per admitted request, keyed by request index
    tokens: Vec<(usize, Vec<u16>)>,
    kv_bytes: usize,
    prefix_hit_tokens: u64,
    prefill_passes: u64,
    tok_per_s: f64,
}

fn argmax(row: &[f32]) -> u16 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as u16
}

/// Admit as many requests as the policy's accounting allows against
/// the fixed budget, then run them all to completion concurrently.
fn run_mode(
    m: &ModelWeights,
    prompts: &[Vec<u16>],
    max_new: usize,
    mode: Mode,
) -> ModeOut {
    let kv = KvConfig {
        page_positions: KV_PAGE,
        pages: BUDGET_PAGES,
        prefix_entries: if mode == Mode::PagedPrefix { 8 } else { 0 },
    };
    let mut batch =
        DecodeBatch::with_kv(m, MAX_BATCH, MAX_CTX, PREFILL_CHUNK, kv);

    if mode == Mode::PagedPrefix {
        // a completed earlier request published the shared head — the
        // steady-state a long-running server converges to
        let si = batch.admit(prompts[0].len()).unwrap();
        prefill_into(m, &mut batch, si, &prompts[0]);
        batch.cache_prefix(si, &prompts[0]);
        batch.retire(si);
    }

    // admission wave: one request at a time until the policy's own
    // accounting says the budget is spent
    let mut admitted: Vec<(usize, usize)> = Vec::new(); // (request, hit)
    for (ri, p) in prompts.iter().enumerate() {
        if batch.len() == MAX_BATCH {
            break;
        }
        let limit = p.len() + max_new;
        let (cap, hit) = match mode {
            Mode::Slab => (MAX_CTX, 0),
            Mode::Paged => (limit, 0),
            Mode::PagedPrefix => (limit, batch.prefix_peek(p)),
        };
        let need = batch.pages_for(cap) - batch.pages_for(hit);
        if batch.available_pages() < need {
            break;
        }
        let si = batch.admit_prompt(cap, p, hit).unwrap();
        assert_eq!(si, admitted.len());
        assert!(
            batch.try_reserve(si, cap - hit),
            "{}: accounting admitted more than the pool holds",
            mode.name()
        );
        admitted.push((ri, hit));
    }
    assert!(!admitted.is_empty(), "{}: nothing admitted", mode.name());

    // run everything that got in: chunked prefill, then greedy decode
    let t0 = Instant::now();
    let p0 = weight_passes();
    let mut tokens: Vec<(usize, Vec<u16>)> = Vec::new();
    for (si, &(ri, hit)) in admitted.iter().enumerate() {
        let logits =
            prefill_into(m, &mut batch, si, &prompts[ri][hit..]).to_vec();
        tokens.push((ri, vec![argmax(&logits)]));
    }
    let prefill_passes = weight_passes() - p0;
    for _ in 1..max_new {
        let inputs: Vec<(usize, u16)> = tokens
            .iter()
            .enumerate()
            .map(|(si, (_, t))| (si, *t.last().unwrap()))
            .collect();
        let rows: Vec<Vec<u16>> = {
            let t = batch.step(m, &inputs);
            (0..inputs.len()).map(|r| vec![argmax(t.row(r))]).collect()
        };
        for (si, r) in rows.into_iter().enumerate() {
            tokens[si].1.extend(r);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    ModeOut {
        admitted: admitted.len(),
        kv_bytes: batch.kv_bytes(),
        prefix_hit_tokens: batch.prefix_hit_tokens(),
        prefill_passes,
        tok_per_s: (admitted.len() * max_new) as f64 / wall.max(1e-9),
        tokens,
    }
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new(
        "kv_paging",
        "paged KV: admitted concurrency at a fixed page budget",
    );
    let max_new = if Bench::fast() { 8 } else { 16 };
    let m = random_model_sized(9, 2, 32, 2, 64, 64, MAX_CTX);
    // every request shares a page-aligned system head, then diverges
    let head: Vec<u16> = (0..HEAD_LEN).map(|i| (7 + 5 * i) as u16 % 60).collect();
    let prompts: Vec<Vec<u16>> = (0..MAX_BATCH)
        .map(|ri| {
            let mut p = head.clone();
            p.extend((0..TAIL_LEN).map(|j| (1 + 3 * ri + 7 * j) as u16 % 60));
            p
        })
        .collect();
    println!(
        "budget {BUDGET_PAGES} pages × {KV_PAGE} positions, max_ctx \
         {MAX_CTX}, prompt {} (shared head {HEAD_LEN}), max_new {max_new}",
        prompts[0].len()
    );

    let mut outs: Vec<(Mode, ModeOut)> = Vec::new();
    println!("\n— admission policy sweep (same pool, same workload) —");
    header(&["mode", "admitted", "kv-KB", "hit-tok", "tok/s"]);
    for mode in [Mode::Slab, Mode::Paged, Mode::PagedPrefix] {
        let o = run_mode(&m, &prompts, max_new, mode);
        println!(
            "{:>12}{:>12}{:>12}{:>12}{:>12.0}",
            mode.name(),
            o.admitted,
            o.kv_bytes / 1024,
            o.prefix_hit_tokens,
            o.tok_per_s
        );
        outs.push((mode, o));
    }

    // parity: every request admitted by several modes decoded the same
    // tokens — paging and prefix reuse are capacity features, not
    // output changes
    let slab = &outs[0].1;
    for (mode, o) in &outs[1..] {
        for (ri, toks) in &o.tokens {
            if let Some((_, want)) = slab.tokens.iter().find(|(r, _)| r == ri) {
                assert_eq!(
                    toks, want,
                    "{}: request {ri} diverged from slab output",
                    mode.name()
                );
            }
        }
    }
    let (slab_n, paged_n, prefix_n) =
        (outs[0].1.admitted, outs[1].1.admitted, outs[2].1.admitted);
    assert!(
        paged_n >= 2 * slab_n,
        "observed-residency accounting must at least double admitted \
         concurrency ({paged_n} vs {slab_n})"
    );
    assert!(prefix_n > paged_n, "prefix reuse must admit more still");
    // shared head costs zero weight passes: one tail chunk per seq,
    // instead of ceil(prompt/chunk) chunks
    let chunks_full = prompts[0].len().div_ceil(PREFILL_CHUNK) as u64;
    let per_chunk = (m.cfg.n_layers * 7) as u64;
    assert_eq!(
        outs[1].1.prefill_passes,
        paged_n as u64 * chunks_full * per_chunk,
        "paged mode prefills the whole prompt"
    );
    assert_eq!(
        outs[2].1.prefill_passes,
        prefix_n as u64 * per_chunk,
        "cached head must prefill with ZERO weight passes (tail only)"
    );
    assert_eq!(
        outs[2].1.prefix_hit_tokens,
        (prefix_n * HEAD_LEN) as u64,
        "every prefix-mode admission serves the head from cache"
    );

    let mut rows: Vec<Json> = Vec::new();
    for (mode, o) in &outs {
        rows.push(rec(&[
            ("section", Json::str("kv_admission")),
            ("mode", Json::str(mode.name())),
            ("budget_pages", Json::num(BUDGET_PAGES as f64)),
            ("admitted", Json::num(o.admitted as f64)),
            ("kv_bytes", Json::num(o.kv_bytes as f64)),
            ("prefix_hit_tokens", Json::num(o.prefix_hit_tokens as f64)),
            ("prefill_passes", Json::num(o.prefill_passes as f64)),
            ("tok_per_s", Json::num(o.tok_per_s)),
            ("parity", Json::Bool(true)),
        ]));
    }
    for r in &rows {
        b.row("kv_admission", r.clone());
    }
    let mut out = Json::obj();
    out.set("bench", Json::str("kv_paging"));
    out.set("max_new", Json::num(max_new as f64));
    out.set("rows", Json::Arr(rows));
    std::fs::write("BENCH_kv.json", out.to_string())?;
    println!("[wrote BENCH_kv.json]");

    println!(
        "KV-BENCH OK: slab {slab_n} → paged {paged_n} → paged+prefix \
         {prefix_n} admitted at {BUDGET_PAGES} pages"
    );
    b.finish();
    Ok(())
}
