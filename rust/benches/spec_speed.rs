//! Speculative-serving throughput: dense-verified tokens drafted by a
//! sealed 70 %-pruned variant, swept over draft depth K ∈ {0 (off), 2,
//! 4, 8} at serving widths 1 and 4. The deployment question behind
//! Mosaic's "up to 67 % faster" claim, asked end-to-end: how much of
//! the pruned model's speed survives as DENSE-QUALITY token throughput
//! once the dense parent verifies every token?
//!
//! Every speculative row is parity-checked against the K = 0 baseline
//! before it is recorded — the bit-identity contract is an invariant
//! here, not an assumption — and each row carries its acceptance rate
//! (accepted / drafted) so the tok/s trajectory can be read against
//! how often the draft actually guessed right.
//!
//! Emits `BENCH_spec.json` (tok/s, acceptance, p95) via
//! `make bench-spec` for cross-PR perf tracking. Artifact-free: runs
//! on random weights anywhere.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use mosaic::bench_support::{header, rec, Bench};
use mosaic::data::trace::{generate, percentiles, Arrival, TraceConfig};
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::serve::{
    wait_reply, ModelRegistry, ServeConfig, Server, SpecRequest,
    SubmitSpec,
};
use mosaic::util::json::Json;

struct DriveOut {
    tokens: Vec<Vec<u16>>,
    tok_per_s: f64,
    p95_ms: f64,
    drafted: u64,
    accepted: u64,
}

/// Replay `trace` routed to `model` (k = None → plain entry, Some →
/// per-request spec depth), collecting tokens in request order for the
/// parity check and the pair engine's counter deltas for this run.
fn drive(
    srv: &Server,
    model: &str,
    k: Option<usize>,
    trace: &[mosaic::data::trace::TraceItem],
) -> DriveOut {
    let stats = srv.model_stats("pair").expect("pair registered");
    let d0 = stats.drafted.load(Ordering::Relaxed);
    let a0 = stats.draft_accepted.load(Ordering::Relaxed);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for item in trace {
        let spec = SubmitSpec {
            model: Some(model.into()),
            spec: k.map(|k| SpecRequest { draft: None, k: Some(k) }),
            ..SubmitSpec::greedy(&item.prompt, item.max_new)
        };
        let sent = Instant::now();
        let rx = srv.submit_spec(spec).expect("queue sized for trace");
        pending.push((sent, rx));
    }
    let mut tokens = Vec::new();
    let mut lat = Vec::new();
    let mut n_tok = 0usize;
    for (sent, rx) in pending {
        let r = wait_reply(&rx, Duration::from_secs(120)).unwrap();
        lat.push(sent.elapsed().as_secs_f64() * 1e3);
        n_tok += r.tokens.len();
        tokens.push(r.tokens);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (_, p95, _) = percentiles(lat);
    DriveOut {
        tokens,
        tok_per_s: n_tok as f64 / wall,
        p95_ms: p95,
        drafted: stats.drafted.load(Ordering::Relaxed) - d0,
        accepted: stats.draft_accepted.load(Ordering::Relaxed) - a0,
    }
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new(
        "spec_speed",
        "self-speculative serving: pruned draft, dense verify",
    );
    let n_requests = if Bench::fast() { 12 } else { 32 };
    // closed-loop: all requests at t=0 so tok/s reflects engine speed
    let trace = generate(&TraceConfig {
        arrival: Arrival::Batch,
        rate: 150.0,
        n_requests,
        prompt_len_mean: 12,
        prompt_len_max: 24,
        max_new: 16,
        ..Default::default()
    });

    // dense target + sealed 70 %-magnitude-pruned draft — the Mosaic
    // self-speculative topology on random weights
    let dense = random_model_sized(9, 4, 256, 8, 704, 512, 128);
    let mut draft = dense.clone();
    for l in draft.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    draft.compact();
    println!(
        "dense {} KB, sealed draft {} KB resident",
        dense.resident_bytes() / 1024,
        draft.resident_bytes() / 1024
    );

    let widths: &[usize] = if Bench::fast() { &[1] } else { &[1, 4] };
    let ks: &[usize] = &[0, 2, 4, 8];
    let mut summary: Vec<Json> = Vec::new();
    println!("\n— K sweep (draft=sealed70, verify=dense) —");
    header(&["width", "k", "tok/s", "p95-ms", "accept", "vs-off"]);
    for &w in widths {
        let mut reg = ModelRegistry::new();
        reg.register("dense", dense.clone())?;
        reg.register("draft70", draft.clone())?;
        reg.register_spec("pair", "dense", "draft70", 8)?;
        let srv = Server::start_registry(
            reg,
            ServeConfig {
                max_batch: w,
                max_queue: 256,
                ..Default::default()
            },
            0,
        )?;
        // K = 0 baseline: target-only serving through the plain dense
        // entry — what "speculation off" actually means in production
        let mut off_tok_per_s = 0.0;
        let mut off_tokens: Vec<Vec<u16>> = Vec::new();
        for &k in ks {
            let d = if k == 0 {
                drive(&srv, "dense", None, &trace)
            } else {
                drive(&srv, "pair", Some(k), &trace)
            };
            if k == 0 {
                off_tok_per_s = d.tok_per_s;
                off_tokens = d.tokens.clone();
            } else {
                // the contract the whole feature stands on: dense-
                // verified speculative output IS the dense output
                assert_eq!(
                    d.tokens, off_tokens,
                    "width {w} k {k}: speculative tokens diverged"
                );
            }
            let acceptance = if d.drafted > 0 {
                d.accepted as f64 / d.drafted as f64
            } else {
                0.0
            };
            println!(
                "{w:>12}{k:>12}{:>12.0}{:>12.2}{:>12.2}{:>12.2}",
                d.tok_per_s,
                d.p95_ms,
                acceptance,
                d.tok_per_s / off_tok_per_s.max(1e-9)
            );
            let row = rec(&[
                ("section", Json::str("spec_sweep")),
                ("width", Json::num(w as f64)),
                ("k", Json::num(k as f64)),
                ("tok_per_s", Json::num(d.tok_per_s)),
                ("p95_ms", Json::num(d.p95_ms)),
                ("acceptance", Json::num(acceptance)),
                ("drafted", Json::num(d.drafted as f64)),
                ("accepted", Json::num(d.accepted as f64)),
                (
                    "speedup_vs_off",
                    Json::num(d.tok_per_s / off_tok_per_s.max(1e-9)),
                ),
                ("parity", Json::Bool(true)),
            ]);
            b.row("spec_sweep", row.clone());
            summary.push(row);
        }
        srv.shutdown();
    }

    // machine-readable perf-trajectory file (make bench-spec)
    let mut out = Json::obj();
    out.set("bench", Json::str("spec_speed"));
    out.set("n_requests", Json::num(n_requests as f64));
    out.set("rows", Json::Arr(summary));
    std::fs::write("BENCH_spec.json", out.to_string())?;
    println!("[wrote BENCH_spec.json]");

    b.finish();
    Ok(())
}
