//! Table VI: perplexity and accuracy before vs after LoRA fine-tuning of
//! the 80 %-pruned LLaMa-3.1-8B proxy, per pruning method.
//! Paper shape: every method recovers; projection starts best and stays
//! best after fine-tuning (e.g. 82→27.5 PPL vs global 220→42).

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::{mean_accuracy, perplexity_native};
use mosaic::finetune::{merge_lora, train_lora, LoraConfig};
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("tab6_finetune_quality",
                           "PPL/acc before vs after LoRA @80%");
    let mut mo = Mosaic::load("tl31")?;
    let seq = mo.dense.cfg.ctx.min(64);
    let wt = mo.store.split("wikitext2s")?;
    let (rows, n_rows, s) = mo.finetune_rows()?;
    let steps = if Bench::fast() { 20 } else { 100 };
    let samples = Bench::samples();
    println!("{:>11} {:>10} {:>8} {:>10} {:>8}", "method",
             "ppl-before", "acc-b%", "ppl-after", "acc-a%");
    for u in [Uniformity::Global, Uniformity::Layer,
              Uniformity::Projection] {
        let (pruned, _) = mo.prune(0.8, u, Category::Unstructured,
                                   samples)?;
        let ppl_b = perplexity_native(&pruned, &wt, seq, 16);
        let acc_b = mean_accuracy(&pruned, &mo.store)?;
        let cfg = LoraConfig { steps, ..Default::default() };
        let rt = mo.runtime()?;
        rt.set_weights(&pruned)?;
        let res = train_lora(rt, &rows, n_rows, s, &cfg)?;
        let mut merged = pruned.clone();
        merge_lora(&mut merged, &res.lora, cfg.rank, cfg.alpha);
        let ppl_a = perplexity_native(&merged, &wt, seq, 16);
        let acc_a = mean_accuracy(&merged, &mo.store)?;
        println!("{:>11} {:>10.2} {:>8.2} {:>10.2} {:>8.2}",
                 u.name(), ppl_b, acc_b, ppl_a, acc_a);
        b.row("series", rec(&[
            ("method", Json::str(u.name())),
            ("ppl_before", Json::num(ppl_b)),
            ("acc_before", Json::num(acc_b)),
            ("ppl_after", Json::num(ppl_a)),
            ("acc_after", Json::num(acc_a)),
        ]));
    }
    b.finish();
    Ok(())
}
