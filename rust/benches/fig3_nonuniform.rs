//! Fig. 3: motivation plot — normalized accuracy and perplexity of the
//! LLaMa-3-8B proxy as parameters are removed by uniform vs non-uniform
//! pruning. Paper shape: non-uniform holds accuracy to higher sparsity
//! (the "same loss, ~25 % more parameters removable" argument).

use mosaic::bench_support::{header, rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::{mean_accuracy, perplexity_native};
use mosaic::prune::{Category, Uniformity};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fig3_nonuniform",
                           "uniform vs non-uniform accuracy/PPL");
    let mut mo = Mosaic::load("tl3")?;
    let seq = mo.dense.cfg.ctx.min(64);
    let wt = mo.store.split("wikitext2s")?;
    let samples = Bench::samples();
    let dense_acc = mean_accuracy(&mo.dense, &mo.store)?;
    let sweep: Vec<f64> = if Bench::fast() {
        vec![0.4, 0.8]
    } else {
        vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8]
    };
    header(&["sparsity", "method", "norm-acc", "ppl"]);
    for &p in &sweep {
        for (label, u) in [("uniform", Uniformity::Global),
                           ("non-uniform", Uniformity::Projection)] {
            let m = mo.prune(p, u, Category::Unstructured, samples)?.0;
            let acc = mean_accuracy(&m, &mo.store)?;
            let ppl = perplexity_native(&m, &wt, seq, 16);
            let norm = acc / dense_acc;
            println!("{:>12.0}%{:>12}{:>12.3}{:>12.2}",
                     p * 100.0, label, norm, ppl);
            b.row("series", rec(&[
                ("sparsity", Json::num(p)),
                ("method", Json::str(label)),
                ("normalized_accuracy", Json::num(norm)),
                ("ppl", Json::num(ppl)),
            ]));
        }
    }
    b.finish();
    Ok(())
}
