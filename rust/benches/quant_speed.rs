//! Quantized-storage perf trajectory: the sparsity × precision × width
//! sweep over the runtime storage kernels (f32/f16/csr/i8/i4/csr8),
//! plus the end-to-end acceptance row — a pruned+quantized model whose
//! csr8 seal is strictly smaller resident than its f16/CSR-f16 seal,
//! round-trips export/load byte-exactly, and serves over real TCP with
//! greedy output equal to a local engine decode.
//!
//! Every kernel row is parity-checked before it is recorded: the sealed
//! kernel's output must be **bit-identical** to the same kernel run on
//! the decoded-dense (`to_dense()`) copy of that seal. That is the
//! subsystem's contract — quantization changes the weights once, at
//! seal time; the kernels themselves are exact (axpy order fixed, no
//! FMA) — so parity failures abort the bench rather than record a row.
//!
//! Emits `BENCH_quant.json` via `make bench-quant` for cross-PR perf
//! tracking. Artifact-free: runs on random weights anywhere.

use std::time::Instant;

use mosaic::bench_support::{header, rec, Bench};
use mosaic::deploy::{self, QuantSpec};
use mosaic::model::engine::{argmax, decode_step, DecodeState};
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::quant::{quantize_model, QuantConfig};
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::{ModelRegistry, ServeConfig, Server};
use mosaic::tensor::{matmul_storage, matvec_storage, ProjStorage, Tensor};
use mosaic::util::json::Json;
use mosaic::util::rng::Pcg32;

/// Zero a deterministic `sparsity` fraction of a tensor by magnitude.
fn sparsify(t: &mut Tensor, sparsity: f64) {
    if sparsity <= 0.0 {
        return;
    }
    let sc = scores(t, None, Metric::Magnitude);
    mask_lowest(t, &sc, sparsity);
}

/// One decode pass at `width`: matvec for width 1, matmul above.
fn run_kernel(
    s: &ProjStorage,
    x1: &[f32],
    xw: &Tensor,
    width: usize,
) -> Vec<f32> {
    if width == 1 {
        let mut out = vec![0.0f32; s.shape()[1]];
        matvec_storage(x1, s, &mut out);
        out
    } else {
        matmul_storage(xw, s).data
    }
}

/// 70 %-magnitude-pruned random model, GPTQ-quantized to i8 and sealed
/// through the cost table (256-dim shapes land every projection in the
/// csr8 window at group 128).
fn pruned_quantized(seed: u64, n_layers: usize) -> ModelWeights {
    let mut m = random_model_sized(seed, n_layers, 256, 8, 704, 512, 128);
    for l in m.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            sparsify(s.dense_mut(), 0.7);
        }
    }
    quantize_model(&mut m, None, QuantConfig { bits: 8, group: 128 });
    m.compact_q(Some(QuantSpec::i8(128)));
    m
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new(
        "quant_speed",
        "quantized storage kernels: sparsity x precision x width",
    );
    let mut summary: Vec<Json> = Vec::new();
    let mut rng = Pcg32::seeded(5);

    // ---- kernel sweep: sized past L2 so the weight stream dominates,
    //      as in a real lm_head/ffn projection (perf_hotpath sizing)
    let (k, n) = if Bench::fast() {
        (256usize, 1024usize)
    } else {
        (1024usize, 4096usize)
    };
    let sparsities: &[f64] =
        if Bench::fast() { &[0.0, 0.7] } else { &[0.0, 0.5, 0.7, 0.9] };
    let widths: &[usize] = if Bench::fast() { &[1, 8] } else { &[1, 2, 8] };
    let base_reps = if Bench::fast() { 12 } else { 48 };

    let x1: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    println!("\n— storage kernels, {k}x{n}, group 128 —");
    header(&["sparsity", "backend", "width", "us", "vs-f32", "res-KB"]);
    for &sp in sparsities {
        let mut w =
            Tensor::new((0..k * n).map(|_| rng.normal()).collect(), vec![k, n]);
        sparsify(&mut w, sp);
        let backends = [
            ("f32", ProjStorage::from_dense(w.clone())),
            ("f16", ProjStorage::seal_f16(&w)),
            ("csr", ProjStorage::seal_csr(&w)),
            ("i8", ProjStorage::seal_i8(&w, 128)),
            ("i4", ProjStorage::seal_i4(&w, 128)),
            ("csr8", ProjStorage::seal_csr_i8(&w, 128)),
        ];
        for &width in widths {
            let xw = Tensor::new(
                (0..width * k).map(|_| rng.normal()).collect(),
                vec![width, k],
            );
            let mut f32_us = 0.0f64;
            for (name, s) in backends.iter() {
                // parity gate: sealed kernel == same kernel over the
                // decoded-dense copy, bit for bit, before timing
                let got = run_kernel(s, &x1, &xw, width);
                let oracle = ProjStorage::from_dense(s.to_dense());
                let want = run_kernel(&oracle, &x1, &xw, width);
                for (i, (a, o)) in got.iter().zip(want.iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        o.to_bits(),
                        "{name} width {width} sparsity {sp}: \
                         out[{i}] diverged from decoded-dense oracle"
                    );
                }
                let reps = (base_reps / width).max(4);
                for _ in 0..2 {
                    run_kernel(s, &x1, &xw, width); // warm
                }
                let t0 = Instant::now();
                for _ in 0..reps {
                    run_kernel(s, &x1, &xw, width);
                }
                let us =
                    t0.elapsed().as_secs_f64() / reps as f64 * 1e6;
                if *name == "f32" {
                    f32_us = us;
                }
                let speedup = if us > 0.0 { f32_us / us } else { 0.0 };
                println!(
                    "{sp:>12.1}{name:>12}{width:>12}{us:>12.1}\
                     {speedup:>12.2}{:>12}",
                    s.resident_bytes() / 1024
                );
                let row = rec(&[
                    ("section", Json::str("kernel_sweep")),
                    ("sparsity", Json::num(sp)),
                    ("backend", Json::str(name)),
                    ("width", Json::num(width as f64)),
                    ("us", Json::num(us)),
                    ("speedup_vs_f32", Json::num(speedup)),
                    (
                        "resident_bytes",
                        Json::num(s.resident_bytes() as f64),
                    ),
                    ("parity", Json::Bool(true)),
                ]);
                b.row("kernel_sweep", row.clone());
                summary.push(row);
            }
        }
    }

    // ---- e2e acceptance row: pruned+quantized (csr8) vs the f16/CSR
    //      seal of the same pruned weights — strictly smaller resident,
    //      byte-exact export round trip, TCP serve parity
    println!("\n— e2e: pruned 70% + i8:128 quantized, csr8 sealed —");
    let n_layers = if Bench::fast() { 2 } else { 4 };
    let q = pruned_quantized(9, n_layers);
    let csr8_projs = q
        .layers
        .iter()
        .flat_map(|l| l.projs.iter())
        .filter(|s| s.encoding_name() == "csr8")
        .count();
    assert!(csr8_projs > 0, "no projection landed in the csr8 window");
    let mut f16_seal = pruned_quantized(9, n_layers);
    f16_seal.decompact();
    f16_seal.compact(); // same (quantize-rounded) weights, no QuantSpec
    assert!(
        q.resident_bytes() < f16_seal.resident_bytes(),
        "csr8 seal must be strictly smaller resident: {} vs {}",
        q.resident_bytes(),
        f16_seal.resident_bytes()
    );
    println!(
        "csr8 seal {} KB vs f16/csr seal {} KB ({csr8_projs} csr8 projs)",
        q.resident_bytes() / 1024,
        f16_seal.resident_bytes() / 1024
    );

    // byte-exact export / load / re-export
    let path = std::env::temp_dir().join("mosaic_quant_speed.mosaic");
    let path2 = std::env::temp_dir().join("mosaic_quant_speed2.mosaic");
    let shipped = deploy::export_model(&q, &path)?;
    let loaded = deploy::load_encoded(&path)?;
    assert_eq!(q.resident_bytes(), loaded.resident_bytes());
    deploy::export_model(&loaded, &path2)?;
    assert_eq!(
        std::fs::read(&path)?,
        std::fs::read(&path2)?,
        "re-export of the loaded model must be the same file"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&path2).ok();
    println!("export round trip byte-exact ({shipped} B shipped)");

    // serve over real TCP; greedy replies must equal a local decode
    let local = loaded;
    let mut reg = ModelRegistry::new();
    reg.register("q70i8", q)?;
    let srv = Server::start_registry(reg, ServeConfig::default(), 0)?;
    let mut client = Client::connect(srv.addr)?;
    let max_new = 8usize;
    let mut served_tokens = 0usize;
    let t0 = Instant::now();
    for prompt in [vec![2u16, 9, 4], vec![1, 7, 3, 5]] {
        let r = client.generate(
            &GenRequest::greedy(&prompt).max_new(max_new).model("q70i8"),
        )?;
        let mut st = DecodeState::new(&local, local.cfg.ctx);
        for &t in &prompt[..prompt.len() - 1] {
            decode_step(&local, &mut st, t);
        }
        let mut want = Vec::new();
        let mut last = *prompt.last().unwrap();
        for _ in 0..max_new {
            let logits = decode_step(&local, &mut st, last);
            last = argmax(logits) as u16;
            want.push(last);
        }
        assert_eq!(
            r.tokens, want,
            "served greedy tokens must match the local engine"
        );
        served_tokens += r.tokens.len();
    }
    let serve_tok_per_s = served_tokens as f64 / t0.elapsed().as_secs_f64();
    srv.shutdown();
    println!(
        "served {served_tokens} greedy tokens over TCP \
         ({serve_tok_per_s:.0} tok/s), parity with local decode"
    );
    let row = rec(&[
        ("section", Json::str("quant_e2e")),
        ("sparsity", Json::num(0.7)),
        ("quant", Json::str("i8:128")),
        ("csr8_projs", Json::num(csr8_projs as f64)),
        ("resident_bytes", Json::num(local.resident_bytes() as f64)),
        (
            "resident_bytes_f16_seal",
            Json::num(f16_seal.resident_bytes() as f64),
        ),
        (
            "resident_ratio",
            Json::num(
                local.resident_bytes() as f64
                    / f16_seal.resident_bytes() as f64,
            ),
        ),
        ("shipped_bytes", Json::num(shipped as f64)),
        ("serve_tok_per_s", Json::num(serve_tok_per_s)),
        ("parity", Json::Bool(true)),
    ]);
    b.row("quant_e2e", row.clone());
    summary.push(row);

    // machine-readable perf-trajectory file (make bench-quant)
    let mut out = Json::obj();
    out.set("bench", Json::str("quant_speed"));
    out.set("shape", Json::str(&format!("{k}x{n}")));
    out.set("rows", Json::Arr(summary));
    std::fs::write("BENCH_quant.json", out.to_string())?;
    println!("[wrote BENCH_quant.json]");

    b.finish();
    Ok(())
}
