//! Table XIII: GPTQ-style quantization (8/4/3/2 bit, group 128) vs
//! Mosaic pruning (20–80 %) on the LLaMa-3.1-8B proxy: zero-shot
//! accuracy, inference speedup, and weight-file compression.
//! Paper shape: 8-bit ≈ lossless but SLOWER without custom kernels
//! (speedup < 1); ≤3-bit collapses; Mosaic keeps accuracy longer AND
//! speeds inference up (1.3–1.45x) with comparable compression.

use mosaic::bench_support::{rec, Bench};
use mosaic::coordinator::Mosaic;
use mosaic::eval::{mean_accuracy, measure_native};
use mosaic::prune::{Category, Uniformity};
use mosaic::quant::{dequantized_model, QuantConfig};
use mosaic::util::json::Json;

/// Dequantization overhead of running b-bit weights through f16 matmuls
/// without fused kernels (modeled on the paper's measured 0.33–0.48x:
/// unpack cost grows as bit-width shrinks).
fn dequant_penalty(bits: u32) -> f64 {
    match bits {
        8 => 1.08,
        4 => 1.12,
        3 => 1.27,
        _ => 2.0,
    }
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("tab13_quantization",
                           "GPTQ quantization vs Mosaic pruning");
    let mut mo = Mosaic::load("tl31")?;
    let samples = Bench::samples();
    let stats = mo.activation_stats(samples)?;
    let dense_perf = measure_native(&mo.dense, 32, 8, 3);
    let dense_acc = mean_accuracy(&mo.dense, &mo.store)?;
    println!("{:<22} {:>7} {:>9} {:>7}", "variant", "acc%", "speedup",
             "comp.");
    println!("{:<22} {:>7.2} {:>9} {:>7}", "dense 16bit", dense_acc,
             "1.00x", "1.00x");

    let bit_sweep: &[u32] = if Bench::fast() { &[4] } else { &[8, 4, 3, 2] };
    for &bits in bit_sweep {
        let cfg = QuantConfig::new(bits);
        let (q, mse) = dequantized_model(&mo.dense, Some(&stats), cfg);
        let acc = mean_accuracy(&q, &mo.store)?;
        // quantized weights run through the same matmuls + unpack cost
        let perf = measure_native(&q, 32, 8, 3);
        let speedup = dense_perf.latency_s
            / (perf.latency_s * dequant_penalty(bits));
        let comp = cfg.compression_vs_f16(cfg.group);
        println!("{:<22} {:>7.2} {:>8.2}x {:>6.2}x",
                 format!("GPTQ {bits}-bit"), acc, speedup, comp);
        b.row("series", rec(&[
            ("variant", Json::str(&format!("gptq_{bits}bit"))),
            ("acc", Json::num(acc)),
            ("speedup", Json::num(speedup)),
            ("compression", Json::num(comp)),
            ("mse", Json::num(mse)),
        ]));
    }

    let p_sweep: &[f64] =
        if Bench::fast() { &[0.6] } else { &[0.2, 0.4, 0.6, 0.8] };
    for &p in p_sweep {
        let (m, _) = mo.prune(p, Uniformity::Projection,
                              Category::Composite, samples)?;
        let acc = mean_accuracy(&m, &mo.store)?;
        let perf = measure_native(&m, 32, 8, 3);
        let speedup = dense_perf.latency_s / perf.latency_s;
        let comp = mo.dense.model_bytes() as f64
            / m.model_bytes() as f64;
        println!("{:<22} {:>7.2} {:>8.2}x {:>6.2}x",
                 format!("Mosaic {:.0}%", p * 100.0), acc, speedup, comp);
        b.row("series", rec(&[
            ("variant", Json::str(&format!("mosaic_{:.0}", p * 100.0))),
            ("acc", Json::num(acc)),
            ("speedup", Json::num(speedup)),
            ("compression", Json::num(comp)),
        ]));
    }
    b.finish();
    Ok(())
}
