//! Speculative-serving parity harness — the determinism contract of
//! `serve::spec` (ISSUE 5 acceptance criteria):
//!
//! 1. **Greedy bit-identity**: for every pruner-sealed draft variant,
//!    at every draft depth K ∈ {1, 4, 8} and serving width ∈ {1, 2, 8},
//!    the pair's output is byte-identical to target-only decoding.
//! 2. **Sampling stream invariance**: a seeded request served through
//!    a pair draws the same PCG32 stream as target-only serving — the
//!    acceptance pattern (which varies wildly across drafts and K)
//!    cannot shift a single token.
//!
//! Drafts cover the pruner families the registry actually seals:
//! magnitude-unstructured at 50/70/90 % (f16 + CSR storage after
//! `compact()`), a 1:4 N:M semi-structured variant, and the dense
//! model itself (the 100 %-acceptance degenerate pair).

use std::time::Duration;

use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::prune::semistructured::nm_prune_projection;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::serve::{
    wait_reply, FinishReason, ModelRegistry, SamplingParams, ServeConfig,
    Server, SpecRequest, SubmitSpec,
};

const T: Duration = Duration::from_secs(60);

fn dense_model() -> ModelWeights {
    random_model_sized(900, 2, 32, 2, 64, 64, 32)
}

/// Magnitude-pruned + sealed (f16/CSR storage) draft variant.
fn sealed_magnitude(dense: &ModelWeights, frac: f64) -> ModelWeights {
    let mut m = dense.clone();
    for l in m.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, frac);
        }
    }
    m.compact();
    m
}

/// 1:4 N:M-pruned + sealed draft variant.
fn sealed_nm(dense: &ModelWeights) -> ModelWeights {
    let mut m = dense.clone();
    for l in m.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            nm_prune_projection(t, &sc, 1, 4);
        }
    }
    m.compact();
    m
}

/// The draft family every parity sweep runs against.
fn drafts(dense: &ModelWeights) -> Vec<(&'static str, ModelWeights)> {
    vec![
        ("mag50", sealed_magnitude(dense, 0.5)),
        ("mag70", sealed_magnitude(dense, 0.7)),
        ("mag90", sealed_magnitude(dense, 0.9)),
        ("nm1:4", sealed_nm(dense)),
        ("self", dense.clone()),
    ]
}

fn prompts() -> Vec<Vec<u16>> {
    (0..8)
        .map(|i| {
            (0..(2 + i % 5))
                .map(|j| (1 + 7 * i + 3 * j) as u16 % 64)
                .collect()
        })
        .collect()
}

fn sampling(i: usize) -> SamplingParams {
    SamplingParams {
        temperature: 0.9,
        top_k: 16,
        top_p: 0.95,
        seed: 4000 + i as u64,
    }
}

/// Serve every prompt through `server`, routed to `model`, greedy or
/// seeded per `sampled`, optionally through the pair at depth `k`.
fn run(
    srv: &Server,
    model: &str,
    k: Option<usize>,
    sampled: bool,
) -> Vec<Vec<u16>> {
    let rxs: Vec<_> = prompts()
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let spec = SubmitSpec {
                model: Some(model.into()),
                sampling: sampled.then(|| sampling(i)),
                spec: k.map(|k| SpecRequest { draft: None, k: Some(k) }),
                ..SubmitSpec::greedy(p, 10)
            };
            srv.submit_spec(spec).unwrap()
        })
        .collect();
    rxs.into_iter()
        .map(|rx| wait_reply(&rx, T).unwrap().tokens)
        .collect()
}

fn server_for(
    dense: &ModelWeights,
    draft: &ModelWeights,
    width: usize,
) -> Server {
    let mut reg = ModelRegistry::new();
    reg.register("dense", dense.clone()).unwrap();
    reg.register("draft", draft.clone()).unwrap();
    // k = 8 default; per-request "spec".k overrides downward
    reg.register_spec("pair", "dense", "draft", 8).unwrap();
    Server::start_registry(
        reg,
        ServeConfig { max_batch: width, ..Default::default() },
        0,
    )
    .unwrap()
}

#[test]
fn greedy_spec_is_byte_identical_for_every_sealed_draft() {
    let dense = dense_model();
    // greedy target-only tokens are width-independent (locked down by
    // serve::tests::batched_serving_matches_width1), so one baseline
    // serves every sweep point
    let baseline = {
        let srv = server_for(&dense, &dense, 1);
        let out = run(&srv, "dense", None, false);
        srv.shutdown();
        out
    };
    for (dname, draft) in drafts(&dense) {
        for width in [1usize, 2, 8] {
            let srv = server_for(&dense, &draft, width);
            for k in [1usize, 4, 8] {
                let got = run(&srv, "pair", Some(k), false);
                assert_eq!(
                    got, baseline,
                    "draft {dname}, width {width}, k {k}: \
                     speculative output must be byte-identical"
                );
            }
            srv.shutdown();
        }
    }
}

#[test]
fn seeded_sampling_stream_is_unchanged_by_acceptance_pattern() {
    let dense = dense_model();
    let baseline = {
        let srv = server_for(&dense, &dense, 1);
        let out = run(&srv, "dense", None, true);
        srv.shutdown();
        out
    };
    // acceptance rates differ enormously between a 90 %-pruned draft
    // and the dense self-draft — the sampled stream must not
    for (dname, draft) in drafts(&dense) {
        for width in [1usize, 8] {
            let srv = server_for(&dense, &draft, width);
            for k in [1usize, 4, 8] {
                let got = run(&srv, "pair", Some(k), true);
                assert_eq!(
                    got, baseline,
                    "draft {dname}, width {width}, k {k}: \
                     seeded sampling must consume the same RNG stream"
                );
            }
            srv.shutdown();
        }
    }
}

#[test]
fn self_draft_accepts_everything() {
    // draft == target: every proposal is the target's own argmax, so
    // every drafted token of a length-finished greedy request is
    // accepted (a stop can truncate a round midway; those runs are
    // checked for the weaker invariant)
    let dense = dense_model();
    let srv = server_for(&dense, &dense, 2);
    for (i, p) in prompts().iter().enumerate() {
        let spec = SubmitSpec {
            model: Some("pair".into()),
            spec: Some(SpecRequest { draft: None, k: Some(4) }),
            ..SubmitSpec::greedy(p, 10)
        };
        let r = wait_reply(&srv.submit_spec(spec).unwrap(), T).unwrap();
        let u = r.spec.expect("pair reply carries counters");
        assert!(u.accepted <= u.drafted, "prompt {i}: {u:?}");
        if r.finish_reason == FinishReason::Length {
            assert_eq!(
                u.accepted, u.drafted,
                "prompt {i}: self-draft must accept every proposal"
            );
            assert!(u.drafted > 0, "prompt {i}: k=4 must draft");
        }
    }
    // engine-level counters aggregate the same way
    let stats = srv.model_stats("pair").unwrap();
    use std::sync::atomic::Ordering::Relaxed;
    assert!(stats.drafted.load(Relaxed) >= stats.draft_accepted.load(Relaxed));
    assert!(stats.spec_rounds.load(Relaxed) > 0);
    srv.shutdown();
}

#[test]
fn streaming_through_a_pair_mirrors_the_reply() {
    // stream events are emitted as tokens COMMIT (post-verify), so a
    // streamed spec request must frame exactly like a plain one
    let dense = dense_model();
    let draft = sealed_magnitude(&dense, 0.7);
    let srv = server_for(&dense, &draft, 2);
    let spec = SubmitSpec {
        model: Some("pair".into()),
        stream: true,
        ..SubmitSpec::greedy(&[1, 5, 9], 8)
    };
    let rx = srv.submit_spec(spec).unwrap();
    let mut streamed = Vec::new();
    let reply = loop {
        match rx.recv_timeout(T).unwrap() {
            mosaic::serve::Event::Token { index, token, .. } => {
                assert_eq!(index, streamed.len(), "event order");
                streamed.push(token);
            }
            mosaic::serve::Event::Done(r) => break r,
        }
    };
    assert_eq!(streamed, reply.tokens, "stream must mirror the reply");
    srv.shutdown();
}
