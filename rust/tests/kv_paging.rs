//! Paged-KV bit-parity: a `DecodeBatch` on a block-granular page pool
//! must produce logits BYTE-IDENTICAL to the degenerate one-slab-per-
//! sequence layout (`KvConfig::slab_oracle`) under every interleaving
//! of admission, chunked prefill, decode, speculative rollback
//! (`truncate`) and retire — including sequences whose prompt head is
//! served from the prefix cache and sequences that write into shared
//! (copy-on-write) tail pages. The page walk visits positions in the
//! same ascending order as the flat slab, so equality is exact, not
//! approximate.

use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::{
    prefill_into, DecodeBatch, KvConfig, ModelWeights, PREFILL_CHUNK,
};
use mosaic::tensor::storage::weight_passes;
use mosaic::util::rng::Pcg32;

const MAX_BATCH: usize = 3;
const MAX_CTX: usize = 64;
const PAGE: usize = 8;

/// Host-side mirror of one live sequence: every token actually
/// consumed (so truncate/re-feed and `cache_prefix` stay honest) and
/// the admitted capacity.
struct Mirror {
    fed: Vec<u16>,
    cap: usize,
}

fn paged_config() -> KvConfig {
    KvConfig {
        // worst case MAX_BATCH × ceil(MAX_CTX/PAGE) pages for live
        // sequences, plus slack so prefix-cache entries survive
        page_positions: PAGE,
        pages: MAX_BATCH * MAX_CTX.div_ceil(PAGE) + PAGE,
        prefix_entries: 16,
    }
}

/// Assert every logits row of one fused step is byte-equal across the
/// two engines.
fn step_both(
    m: &ModelWeights,
    paged: &mut DecodeBatch,
    slab: &mut DecodeBatch,
    inputs: &[(usize, u16)],
    what: &str,
) {
    let got: Vec<Vec<f32>> = {
        let t = paged.step(m, inputs);
        (0..inputs.len()).map(|r| t.row(r).to_vec()).collect()
    };
    let want: Vec<Vec<f32>> = {
        let t = slab.step(m, inputs);
        (0..inputs.len()).map(|r| t.row(r).to_vec()).collect()
    };
    assert_eq!(got, want, "{what}: paged step must match slab oracle");
}

/// Random prefill/decode/truncate/retire interleavings, paged engine
/// vs flat-slab oracle, byte-equal logits at every step. Admissions
/// flip a coin between a fresh prompt and one sharing a fixed head, so
/// the schedule keeps exercising prefix attach + CoW paths.
fn random_interleaving(seed: u64) {
    let m = random_model_sized(seed, 2, 16, 2, 40, 64, MAX_CTX);
    let mut paged =
        DecodeBatch::with_kv(&m, MAX_BATCH, MAX_CTX, PREFILL_CHUNK, paged_config());
    let mut slab = DecodeBatch::with_kv(
        &m,
        MAX_BATCH,
        MAX_CTX,
        PREFILL_CHUNK,
        KvConfig::slab_oracle(MAX_BATCH, MAX_CTX),
    );
    let mut rng = Pcg32::seeded(seed.wrapping_mul(7).wrapping_add(1));
    let mut live: Vec<Mirror> = Vec::new();
    // two full pages worth of shared prompt head
    let shared_head: Vec<u16> =
        (0..2 * PAGE).map(|i| (5 + 3 * i) as u16 % 60).collect();
    let mut hits = 0usize;

    // Prologue: seed the prefix cache deterministically so attach paths
    // run regardless of what the random schedule does later.
    {
        let pi = paged.admit(MAX_CTX).unwrap();
        let si = slab.admit(MAX_CTX).unwrap();
        let got = prefill_into(&m, &mut paged, pi, &shared_head).to_vec();
        let want = prefill_into(&m, &mut slab, si, &shared_head).to_vec();
        assert_eq!(got, want, "prologue prefill");
        paged.cache_prefix(pi, &shared_head);
        paged.retire(pi);
        slab.retire(si);
        assert_eq!(
            paged.prefix_peek(&shared_head),
            shared_head.len() - 1,
            "freshly cached head must peek (len-capped)"
        );
    }

    for round in 0..200 {
        let op = rng.below(10);
        let eligible: Vec<usize> = (0..live.len())
            .filter(|&i| paged.pos(i) < live[i].cap)
            .collect();

        if live.is_empty() || (live.len() < MAX_BATCH && op < 3) {
            // admit (forced when the batch is empty)
            let mut prompt = if rng.below(2) == 0 {
                shared_head.clone()
            } else {
                Vec::new()
            };
            for _ in 0..1 + rng.below(12) {
                prompt.push(rng.below(60) as u16);
            }
            let cap = (prompt.len() + 8 + rng.below(24)).min(MAX_CTX);
            let hit = paged.prefix_peek(&prompt);
            let pi = paged.admit_prompt(cap, &prompt, hit).unwrap();
            let si = slab.admit(cap).unwrap();
            assert_eq!(pi, si, "round {round}: seq index skew");
            hits += hit;
            // paged feeds only past the cached head; chunk grouping is
            // bit-invariant (prefill_chunk_boundary_parity), so the
            // last-token logits still have to agree exactly
            let got =
                prefill_into(&m, &mut paged, pi, &prompt[hit..]).to_vec();
            let want = prefill_into(&m, &mut slab, si, &prompt).to_vec();
            assert_eq!(got, want, "round {round}: prefill (hit {hit})");
            live.push(Mirror { fed: prompt, cap });
        } else if op < 7 && !eligible.is_empty() {
            // decode a random non-empty subset of the eligible seqs
            let mut inputs: Vec<(usize, u16)> = eligible
                .iter()
                .filter(|_| rng.below(2) == 0)
                .map(|&i| (i, rng.below(60) as u16))
                .collect();
            if inputs.is_empty() {
                let i = eligible[rng.below(eligible.len())];
                inputs.push((i, rng.below(60) as u16));
            }
            step_both(&m, &mut paged, &mut slab, &inputs, "decode");
            for &(i, t) in &inputs {
                live[i].fed.push(t);
            }
        } else if op < 8 {
            // speculative-style rollback: truncate one seq to a random
            // earlier length (often across a page boundary), later
            // decodes re-feed diverging tokens through CoW'd pages
            let i = rng.below(live.len());
            let pos = paged.pos(i);
            if pos > 1 {
                let len = 1 + rng.below(pos - 1);
                paged.truncate(i, len);
                slab.truncate(i, len);
                live[i].fed.truncate(len);
            }
        } else if !live.is_empty() {
            // retire (publishing the head so later admits can share it)
            let i = rng.below(live.len());
            paged.cache_prefix(i, &live[i].fed);
            paged.retire(i);
            slab.retire(i);
            live.swap_remove(i);
        }

        for i in 0..live.len() {
            assert_eq!(
                paged.pos(i),
                slab.pos(i),
                "round {round}: cursor skew on seq {i}"
            );
            assert_eq!(paged.pos(i), live[i].fed.len());
        }
    }
    // Epilogue: one deterministic attach, so the suite covers a prefix
    // hit even if the schedule's coin flips never picked the shared
    // head (the cache entry may have been LRU-evicted meanwhile, so
    // re-publish it first).
    while !live.is_empty() {
        paged.retire(0);
        slab.retire(0);
        live.swap_remove(0);
    }
    let pi = paged.admit(MAX_CTX).unwrap();
    let si = slab.admit(MAX_CTX).unwrap();
    prefill_into(&m, &mut paged, pi, &shared_head);
    prefill_into(&m, &mut slab, si, &shared_head);
    paged.cache_prefix(pi, &shared_head);
    paged.retire(pi);
    slab.retire(si);
    let mut prompt = shared_head.clone();
    prompt.push(33);
    let hit = paged.prefix_peek(&prompt);
    assert_eq!(hit, shared_head.len(), "whole head must be attachable");
    let pi = paged.admit_prompt(MAX_CTX, &prompt, hit).unwrap();
    let si = slab.admit(MAX_CTX).unwrap();
    let got = prefill_into(&m, &mut paged, pi, &prompt[hit..]).to_vec();
    let want = prefill_into(&m, &mut slab, si, &prompt).to_vec();
    assert_eq!(got, want, "epilogue attach parity");
    hits += hit;
    assert!(hits > 0, "no prefix-cache attach ever ran");
}

#[test]
fn random_interleavings_match_slab_oracle() {
    for seed in [11, 12, 13] {
        random_interleaving(seed);
    }
}

/// Rolling back across a page boundary and re-feeding diverging tokens
/// must stay byte-identical to the slab doing the same in-place
/// overwrite.
#[test]
fn truncate_across_page_boundary_matches_slab() {
    let m = random_model_sized(91, 2, 16, 2, 40, 64, MAX_CTX);
    let kv = KvConfig {
        page_positions: PAGE,
        pages: MAX_CTX.div_ceil(PAGE),
        prefix_entries: 0,
    };
    let mut paged = DecodeBatch::with_kv(&m, 1, MAX_CTX, PREFILL_CHUNK, kv);
    let mut slab = DecodeBatch::with_kv(
        &m,
        1,
        MAX_CTX,
        PREFILL_CHUNK,
        KvConfig::slab_oracle(1, MAX_CTX),
    );
    let prompt: Vec<u16> = (0..21).map(|i| (2 + 7 * i) as u16 % 60).collect();
    let p = paged.admit(MAX_CTX).unwrap();
    let s = slab.admit(MAX_CTX).unwrap();
    let got = prefill_into(&m, &mut paged, p, &prompt).to_vec();
    let want = prefill_into(&m, &mut slab, s, &prompt).to_vec();
    assert_eq!(got, want, "prefill");
    // decode past the 24-position page boundary...
    for t in [5u16, 11, 3, 8] {
        step_both(&m, &mut paged, &mut slab, &[(p, t)], "pre-rollback");
    }
    assert_eq!(paged.seq_pages(p), 4, "25 positions span 4 pages");
    // ...roll back across it, then re-feed a diverging continuation
    paged.truncate(p, 22);
    slab.truncate(s, 22);
    for t in [40u16, 2, 33, 17, 29] {
        step_both(&m, &mut paged, &mut slab, &[(p, t)], "post-rollback");
    }
}

/// The CoW contract end-to-end: a sequence attaching a cached head
/// whose last page is only partially claimed (peek caps at `len - 1`)
/// writes into that shared tail page; the write must be redirected to
/// a private copy so the cached bytes — and every later sequence that
/// attaches them — are unaffected.
#[test]
fn cow_tail_page_preserves_cached_prefix() {
    let m = random_model_sized(77, 2, 16, 2, 40, 64, MAX_CTX);
    let kv = KvConfig {
        page_positions: PAGE,
        pages: 2 * MAX_CTX.div_ceil(PAGE) + 2,
        prefix_entries: 4,
    };
    let mut batch = DecodeBatch::with_kv(&m, 2, MAX_CTX, PREFILL_CHUNK, kv);
    let prompt: Vec<u16> =
        (0..2 * PAGE).map(|i| (3 + 5 * i) as u16 % 60).collect();

    // A: prefill the whole prompt, publish it, record a continuation
    let a = batch.admit(MAX_CTX).unwrap();
    let la = prefill_into(&m, &mut batch, a, &prompt).to_vec();
    batch.cache_prefix(a, &prompt);
    let a1 = batch.step(&m, &[(a, 9)]).row(0).to_vec();
    let a2 = batch.step(&m, &[(a, 30)]).row(0).to_vec();
    batch.retire(a);

    // B: same prompt. peek caps at len-1 = 15, so the second cached
    // page arrives as a shared, partially-claimed tail page — feeding
    // position 15 must copy-on-write it, not clobber the cached rows.
    let hit = batch.prefix_peek(&prompt);
    assert_eq!(hit, prompt.len() - 1, "peek caps at prompt len - 1");
    let b = batch.admit_prompt(MAX_CTX, &prompt, hit).unwrap();
    let before = weight_passes();
    let lb = prefill_into(&m, &mut batch, b, &prompt[hit..]).to_vec();
    assert_eq!(
        weight_passes() - before,
        (m.cfg.n_layers * 7) as u64,
        "the 1-token tail must cost exactly one chunk of weight passes"
    );
    assert_eq!(lb, la, "attached prefill must be bit-identical");
    let b1 = batch.step(&m, &[(b, 14)]).row(0).to_vec();
    assert_ne!(b1, a1, "B diverged, logits should differ");

    // C: B wrote into the shared tail page — the cache entry must
    // still peek, and replaying A's continuation through it must
    // reproduce A's logits bit-for-bit.
    let hit = batch.prefix_peek(&prompt);
    assert_eq!(hit, prompt.len() - 1, "CoW must leave the cache usable");
    let c = batch.admit_prompt(MAX_CTX, &prompt, hit).unwrap();
    let lc = prefill_into(&m, &mut batch, c, &prompt[hit..]).to_vec();
    assert_eq!(lc, la, "cache intact after B's CoW write");
    let c1 = batch.step(&m, &[(c, 9)]).row(0).to_vec();
    let c2 = batch.step(&m, &[(c, 30)]).row(0).to_vec();
    assert_eq!(c1, a1, "replayed continuation, step 1");
    assert_eq!(c2, a2, "replayed continuation, step 2");
    assert_eq!(
        batch.prefix_hit_tokens(),
        2 * (prompt.len() - 1) as u64,
        "two attaches, len-1 positions each"
    );
}
