//! Integration: the full RC→PC→eval→finetune pipeline over real
//! artifacts (skips if `make artifacts` has not run).

use mosaic::coordinator::{choose_category, Mosaic};
use mosaic::eval::{mean_accuracy, perplexity_native};
use mosaic::finetune::{merge_lora, train_lora, LoraConfig};
use mosaic::platform;
use mosaic::prune::{Category, Uniformity};

fn load(name: &str) -> Option<Mosaic> {
    Mosaic::load(name).ok()
}

#[test]
fn rank_reuse_across_pruning_levels() {
    let Some(mut mo) = load("tl1_7") else { return };
    // the paper: profile once, reuse the global rank for any p
    let r1 = mo.global_rank(Uniformity::Projection, 8).unwrap();
    let r2 = mo.global_rank(Uniformity::Projection, 8).unwrap();
    assert_eq!(r1.rank, r2.rank, "rank must be deterministic/reusable");
    assert_eq!(r1.rank.len(), mo.dense.cfg.n_layers);
    assert!(r1.rank.iter().all(|r| r.len() == 7));
}

#[test]
fn pruned_ppl_ordering_holds() {
    let Some(mut mo) = load("tl1_7") else { return };
    let wt = mo.store.split("wikitext2s").unwrap();
    let seq = mo.dense.cfg.ctx.min(64);
    let dense = perplexity_native(&mo.dense, &wt, seq, 12);
    let m20 = mo.prune_wanda(0.2, Uniformity::Projection, 8).unwrap();
    let m80 = mo.prune_wanda(0.8, Uniformity::Projection, 8).unwrap();
    let p20 = perplexity_native(&m20, &wt, seq, 12);
    let p80 = perplexity_native(&m80, &wt, seq, 12);
    assert!(dense <= p20 * 1.05, "dense {dense} vs 20% {p20}");
    assert!(p20 < p80, "20% {p20} must beat 80% {p80}");
}

#[test]
fn composite_is_smaller_and_sparser_than_unstructured() {
    let Some(mut mo) = load("tl1_7") else { return };
    let (un, _) = mo
        .prune(0.6, Uniformity::Projection, Category::Unstructured, 8)
        .unwrap();
    let (co, _) = mo
        .prune(0.6, Uniformity::Projection, Category::Composite, 8)
        .unwrap();
    let (st, _) = mo
        .prune(0.6, Uniformity::Projection, Category::Structured, 8)
        .unwrap();
    // bytes: unstructured unchanged; composite between; structured least
    assert_eq!(un.model_bytes(), mo.dense.model_bytes());
    assert!(co.model_bytes() < un.model_bytes());
    assert!(st.model_bytes() < co.model_bytes());
    // removed fraction comparable across categories
    let prunable = mo.dense.cfg.prunable_params();
    for (name, m) in [("un", &un), ("co", &co), ("st", &st)] {
        let removed =
            mosaic::prune::composite::removed_fraction(m, prunable);
        assert!(
            (removed - 0.6).abs() < 0.15,
            "{name} removed {removed}"
        );
    }
}

#[test]
fn accuracy_degrades_to_chance_at_extreme_sparsity() {
    let Some(mut mo) = load("tl1_7") else { return };
    let dense_acc = mean_accuracy(&mo.dense, &mo.store).unwrap();
    let m = mo.prune_wanda(0.95, Uniformity::Global, 8).unwrap();
    let acc = mean_accuracy(&m, &mo.store).unwrap();
    assert!(dense_acc > acc, "dense {dense_acc} vs 95% {acc}");
    // 4x 4-choice (25%) + 3x 2-choice (50%) -> chance mean ≈ 35.7%
    assert!(acc < dense_acc.max(45.0), "collapsed model near chance");
}

#[test]
fn lora_finetune_improves_pruned_model() {
    let Some(mut mo) = load("tl1_7") else { return };
    let (pruned, _) = mo
        .prune(0.8, Uniformity::Projection, Category::Unstructured, 8)
        .unwrap();
    let (rows, n_rows, seq) = mo.store.instruction_rows().unwrap();
    let cfg = LoraConfig { steps: 25, ..Default::default() };
    let rt = mo.runtime().unwrap();
    rt.set_weights(&pruned).unwrap();
    let res = train_lora(rt, &rows, n_rows, seq, &cfg).unwrap();
    let first = res.train_curve.first().unwrap().1;
    let last = res.train_curve.last().unwrap().1;
    assert!(last < first, "loss must decrease: {first} -> {last}");
    // merged model runs and stays finite
    let mut merged = pruned.clone();
    merge_lora(&mut merged, &res.lora, cfg.rank, cfg.alpha);
    let wt = mo.store.split("wikitext2s").unwrap();
    let ppl = perplexity_native(&merged, &wt, pruned.cfg.ctx.min(64), 6);
    assert!(ppl.is_finite() && ppl > 1.0);
}

#[test]
fn deployment_categories_run_on_their_platforms() {
    let Some(mut mo) = load("tl1_7") else { return };
    for pf in platform::testbed() {
        let cat = choose_category(&pf);
        let (m, _) =
            mo.prune(0.6, Uniformity::Projection, cat, 8).unwrap();
        // deployable model must produce finite logits
        let logits =
            mosaic::model::engine::forward_full(&m, &[3, 7, 11, 13]);
        assert!(
            logits.data.iter().all(|x| x.is_finite()),
            "{} ({})",
            pf.name,
            cat.name()
        );
    }
}

#[test]
fn vicuna_variant_loads_and_evaluates() {
    let Some(mut mo) = load("tvic") else { return };
    let acc = mean_accuracy(&mo.dense, &mo.store).unwrap();
    assert!(acc > 20.0 && acc <= 100.0);
    let m = mo.prune_wanda(0.4, Uniformity::Projection, 8).unwrap();
    let wt = mo.store.split("wikitext2s").unwrap();
    let ppl = perplexity_native(&m, &wt, m.cfg.ctx.min(64), 8);
    assert!(ppl.is_finite());
}
