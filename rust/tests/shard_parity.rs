//! Sharded-execution parity suite: one registry entry backed by N
//! shard workers must be observationally identical to the unsharded
//! engine. Three properties:
//!
//! * **bit-identity** — greedy output token-for-token equal to the
//!   single-engine reference for replica groups (N ∈ {2, 4}) and
//!   layer-range pipelines (stages ∈ {2, 3}), over a dense model, a
//!   sealed-70% variant and a pruned+quantized csr8 variant, at batch
//!   widths 1/2/8;
//! * **lifecycle** — a sharded *cold* entry wakes on first request,
//!   idle-unloads as one group (gauges to zero), and re-wakes with
//!   byte-identical output;
//! * **supervision** (feature "chaos") — one replica panicking
//!   mid-stream fails its in-flight requests with exactly one
//!   terminal event each, and the respawned group serves the same
//!   bytes as before.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use mosaic::deploy::QuantSpec;
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::quant::{quantize_model, QuantConfig};
use mosaic::serve::lifecycle::LifecycleState;
use mosaic::serve::{
    wait_reply, HealthState, ModelRegistry, ServeConfig, Server,
    ShardPlan, SubmitSpec,
};

const PROMPTS: &[&[u16]] = &[&[1, 9, 4], &[7, 2, 2, 5, 8], &[3, 60, 11]];
const MAX_NEW: usize = 10;

/// Four layers so a 3-stage pipeline has layers to split (and the
/// resident-byte balancer has real choices to make).
fn dense(seed: u64) -> ModelWeights {
    random_model_sized(seed, 4, 32, 2, 80, 64, 32)
}

/// Magnitude-prune every projection to 70% sparsity and seal into
/// f16/CSR storage.
fn sealed70(dense: &ModelWeights) -> ModelWeights {
    let mut m = dense.clone();
    for l in m.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    m.compact();
    m
}

/// 80%-pruned then i8-quantized, sealed so projections land on csr8
/// runtime storage.
fn csr8(dense: &ModelWeights) -> ModelWeights {
    let mut m = dense.clone();
    for l in m.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.8);
        }
    }
    quantize_model(&mut m, None, QuantConfig { bits: 8, group: 16 });
    m.compact_q(Some(QuantSpec::i8(16)));
    m
}

fn greedy_to(model: &str, prompt: &[u16]) -> SubmitSpec {
    SubmitSpec {
        model: Some(model.to_string()),
        ..SubmitSpec::greedy(prompt, MAX_NEW)
    }
}

/// Serve every prompt against `model`, returning the token streams.
fn serve_all(srv: &Server, model: &str) -> Vec<Vec<u16>> {
    PROMPTS
        .iter()
        .map(|p| {
            let rx = srv.submit_spec(greedy_to(model, p)).expect("admit");
            wait_reply(&rx, Duration::from_secs(60))
                .expect("reply")
                .tokens
        })
        .collect()
}

fn await_lifecycle(srv: &Server, name: &str, want: LifecycleState) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let got = srv.engine_lifecycle(name).expect("registered");
        if got == want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{name}: stuck in {got:?}, wanted {want:?}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Every (plan × width × variant) combination replays the unsharded
/// reference token-for-token.
#[test]
fn sharded_greedy_is_bit_identical_across_plans_and_widths() {
    let base = dense(701);
    let variants: Vec<(&str, ModelWeights)> = vec![
        ("dense", base.clone()),
        ("s70", sealed70(&base)),
        ("csr8", csr8(&base)),
    ];
    // the unsharded reference: same weights, one plain engine each
    let mut reg = ModelRegistry::new();
    for (n, m) in &variants {
        reg.register(n, m.clone()).unwrap();
    }
    let hot =
        Server::start_registry(reg, ServeConfig::default(), 0).unwrap();
    let want: Vec<(&str, Vec<Vec<u16>>)> = variants
        .iter()
        .map(|(n, _)| (*n, serve_all(&hot, n)))
        .collect();
    hot.shutdown();

    for plan in [
        ShardPlan::Replica(2),
        ShardPlan::Replica(4),
        ShardPlan::Pipeline(2),
        ShardPlan::Pipeline(3),
    ] {
        for width in [1usize, 2, 8] {
            let mut reg = ModelRegistry::new();
            for (n, m) in &variants {
                reg.register_sharded(n, m.clone(), plan).unwrap();
            }
            let srv = Server::start_registry(
                reg,
                ServeConfig { max_batch: width, ..Default::default() },
                0,
            )
            .unwrap();
            for (n, expect) in &want {
                assert_eq!(
                    &serve_all(&srv, n),
                    expect,
                    "{n} diverged under plan {plan} width {width}"
                );
            }
            srv.shutdown();
        }
    }
}

/// A sharded group also absorbs a *concurrent* burst without reorder
/// damage: every reply matches its prompt's reference stream.
#[test]
fn replica_group_concurrent_burst_is_bit_identical() {
    let base = dense(702);
    let mut reg = ModelRegistry::new();
    reg.register("solo", base.clone()).unwrap();
    reg.register_sharded("rep", base, ShardPlan::Replica(4))
        .unwrap();
    let srv = Server::start_registry(
        reg,
        ServeConfig { max_batch: 2, ..Default::default() },
        0,
    )
    .unwrap();
    let prompts: Vec<Vec<u16>> = (0..16)
        .map(|i| vec![1 + (i % 7) as u16, 5, 9 + (i % 11) as u16])
        .collect();
    let want: Vec<Vec<u16>> = prompts
        .iter()
        .map(|p| {
            let rx = srv.submit_spec(greedy_to("solo", p)).unwrap();
            wait_reply(&rx, Duration::from_secs(60)).unwrap().tokens
        })
        .collect();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| srv.submit_spec(greedy_to("rep", p)).unwrap())
        .collect();
    for (i, (rx, want)) in rxs.iter().zip(&want).enumerate() {
        let r = wait_reply(rx, Duration::from_secs(60)).unwrap();
        assert_eq!(&r.tokens, want, "burst request {i} diverged");
    }
    srv.shutdown();
}

/// Cold-spawn → serve → group idle-unload → re-wake keeps greedy
/// output byte-identical for replica AND pipeline shard groups, and
/// the shared gauges return to zero after the unload.
#[test]
fn sharded_cold_entry_unloads_idle_and_rewakes_bit_identical() {
    let base = dense(703);
    let path =
        std::env::temp_dir().join("shard_parity_cold.mosaic");
    mosaic::deploy::export_model(&base, &path).expect("export");
    // hot unsharded reference
    let mut reg = ModelRegistry::new();
    reg.register("m", base).unwrap();
    let hot =
        Server::start_registry(reg, ServeConfig::default(), 0).unwrap();
    let want = serve_all(&hot, "m");
    hot.shutdown();

    for plan in [ShardPlan::Replica(2), ShardPlan::Pipeline(2)] {
        let mut reg = ModelRegistry::new();
        reg.register_cold_sharded("m", &path, plan).unwrap();
        let srv = Server::start_registry(
            reg,
            ServeConfig {
                max_batch: 2,
                idle_ms: Some(150),
                ..Default::default()
            },
            0,
        )
        .unwrap();
        assert_eq!(
            srv.engine_lifecycle("m"),
            Some(LifecycleState::Cold),
            "plan {plan}: must register cold"
        );
        assert_eq!(serve_all(&srv, "m"), want, "cold wake, plan {plan}");
        assert_eq!(srv.engine_lifecycle("m"), Some(LifecycleState::Hot));
        // the whole group unloads as one unit
        await_lifecycle(&srv, "m", LifecycleState::Cold);
        let stats = srv.model_stats("m").unwrap();
        for (gauge, v) in [
            ("kv_pages_in_use", &stats.kv_pages_in_use),
            ("kv_pages_total", &stats.kv_pages_total),
            ("queue_depth", &stats.queue_depth),
            ("inflight", &stats.inflight),
        ] {
            assert_eq!(
                v.load(Ordering::Relaxed),
                0,
                "{gauge} after group unload, plan {plan}"
            );
        }
        assert_eq!(serve_all(&srv, "m"), want, "re-wake, plan {plan}");
        assert_eq!(
            srv.engine_health("m"),
            Some(HealthState::Healthy),
            "unload cycles must not look like failures"
        );
        srv.shutdown();
    }
    let _ = std::fs::remove_file(&path);
}

#[cfg(feature = "chaos")]
mod chaos {
    use super::*;
    use mosaic::serve::fault::{self, FaultPlan};
    use mosaic::serve::Event;
    use std::sync::{mpsc, Arc};

    /// Zero or more Token events, then exactly one terminal.
    fn drain_terminal(rx: &mpsc::Receiver<Event>) -> Event {
        let deadline = Instant::now() + Duration::from_secs(60);
        let mut terminal: Option<Event> = None;
        loop {
            let left =
                deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(Event::Token { .. }) => {
                    assert!(terminal.is_none(), "token after terminal")
                }
                Ok(ev) => {
                    assert!(
                        terminal.is_none(),
                        "second terminal: {ev:?}"
                    );
                    terminal = Some(ev);
                }
                Err(_) => {
                    return terminal.expect("request hung: no terminal")
                }
            }
        }
    }

    /// One replica panicking mid-stream restarts the WHOLE group:
    /// every in-flight request gets exactly one terminal event, and
    /// the respawned group replays the pre-fault bytes.
    #[test]
    fn replica_shard_panic_respawns_group_bit_identical() {
        let base = dense(704);
        let name = "shard-chaos";
        let mut reg = ModelRegistry::new();
        reg.register_sharded(name, base, ShardPlan::Replica(2))
            .unwrap();
        let srv = Server::start_registry(
            reg,
            ServeConfig {
                max_batch: 2,
                max_queue: 64,
                max_restarts: 10_000,
                restart_backoff_ms: 1,
                ..Default::default()
            },
            0,
        )
        .unwrap();
        let want = serve_all(&srv, name);
        // arm AFTER the reference pass: the 3rd fused step across the
        // group panics — one worker dies mid-stream with its sibling
        // still serving
        let plan =
            Arc::new(FaultPlan::new().panic_at(fault::CP_STEP, 3));
        let guard = fault::arm_guard(name, plan);
        let rxs: Vec<_> = PROMPTS
            .iter()
            .map(|p| srv.submit_spec(greedy_to(name, p)).unwrap())
            .collect();
        let mut errored = 0usize;
        for rx in &rxs {
            if matches!(drain_terminal(rx), Event::Error { .. }) {
                errored += 1;
            }
        }
        assert!(errored > 0, "the armed panic never fired");
        drop(guard);
        // the supervisor respawned the group as one unit; the gauges
        // recover and the output is byte-identical to pre-fault
        let deadline = Instant::now() + Duration::from_secs(30);
        let stats = srv.model_stats(name).unwrap();
        while stats.kv_pages_in_use.load(Ordering::Relaxed) != 0
            || stats.queue_depth.load(Ordering::Relaxed) != 0
        {
            assert!(
                Instant::now() < deadline,
                "gauges stuck after group respawn"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            serve_all(&srv, name),
            want,
            "respawned group diverged"
        );
        srv.shutdown();
    }
}
