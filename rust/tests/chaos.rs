//! Seeded fault-schedule property suite (`make chaos`, feature
//! "chaos"): for ANY deterministic schedule of injected panics, stalls
//! and queue drops, the serving stack must uphold three invariants —
//!
//!   1. every submitted request receives EXACTLY one terminal event
//!      (a Done reply or a typed Error), never zero (hang) and never
//!      two (double delivery);
//!   2. once the dust settles, the gauges return to zero: no leaked
//!      KV pages, no phantom queue depth;
//!   3. a restarted engine serves bit-identical greedy output to an
//!      unfaulted engine built from the same weights.
//!
//! CI runs the fixed seeds below; `exploratory_seed_from_env` adds one
//! run whose seed comes from `CHAOS_SEED` (or the clock when unset)
//! and prints it, so any failure is reproducible with
//! `CHAOS_SEED=<seed> make chaos`.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use mosaic::model::weights::testutil::random_model_sized;
use mosaic::serve::fault::{self, FaultPlan};
use mosaic::serve::{
    Event, ModelRegistry, ServeConfig, Server, ShardPlan, SubmitSpec,
};

/// Fixed CI seeds — chosen arbitrarily, kept stable so a regression
/// bisects cleanly.
const FIXED_SEEDS: [u64; 4] = [11, 42, 4096, 987_654_321];

/// Requests per schedule. Small prompts (3 tokens, far below one KV
/// page) keep the prefix cache empty, so an idle engine must report
/// exactly zero pages in use.
const REQUESTS: usize = 12;

fn model_seed_for(name: &str) -> u64 {
    // any stable function of the name works; engines rebuilt for the
    // bit-identity reference must use the same weights
    name.bytes().map(|b| b as u64).sum::<u64>() + 700
}

fn start(name: &str) -> Server {
    start_sharded(name, ShardPlan::Single)
}

fn start_sharded(name: &str, plan: ShardPlan) -> Server {
    let mut reg = ModelRegistry::new();
    reg.register_sharded(
        name,
        random_model_sized(model_seed_for(name), 2, 16, 2, 40, 64, 16),
        plan,
    )
    .expect("register model");
    let cfg = ServeConfig {
        max_batch: 2,
        max_queue: 64,
        default_model: Some(name.to_string()),
        // the suite is about recovery, not cap exhaustion — give the
        // supervisor room for every panic the schedule injects
        max_restarts: 10_000,
        restart_backoff_ms: 1,
        ..Default::default()
    };
    Server::start_registry(reg, cfg, 0).expect("start server")
}

fn submit(
    srv: &Server,
    i: usize,
) -> Result<mpsc::Receiver<Event>, String> {
    let prompt = vec![
        1 + (i % 7) as u16,
        5 + (i % 3) as u16,
        9 + (i % 11) as u16,
    ];
    srv.submit_spec(SubmitSpec::greedy(&prompt, 6))
        .map_err(|e| format!("admission refused request {i}: {e}"))
}

/// Drain one reply channel: zero or more Token events, then exactly
/// one terminal, then channel closed. Returns Err on hang or double
/// delivery.
fn drain_terminal(rx: &mpsc::Receiver<Event>) -> Result<Event, String> {
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut terminal: Option<Event> = None;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return terminal.ok_or_else(|| {
                "request hung: no terminal event in 60s".to_string()
            });
        }
        match rx.recv_timeout(left) {
            Ok(Event::Token { .. }) => {
                if terminal.is_some() {
                    return Err("token event AFTER terminal".into());
                }
            }
            Ok(ev) => {
                if terminal.is_some() {
                    return Err(format!("second terminal: {ev:?}"));
                }
                terminal = Some(ev);
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return terminal
                    .ok_or_else(|| "channel closed with NO terminal event".into());
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // a received terminal with a still-open channel is
                // fine — the invariant is about event count, not the
                // sender's drop timing
                return terminal.ok_or_else(|| {
                    "request hung: no terminal event in 60s".to_string()
                });
            }
        }
    }
}

/// Poll until both gauges hit zero (the engine may still be mid-restart
/// when the last terminal event lands).
fn await_quiescent(srv: &Server, name: &str) -> Result<(), String> {
    let stats = srv.model_stats(name).ok_or("missing stats")?;
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let pages = stats.kv_pages_in_use.load(Ordering::Relaxed);
        let depth = stats.queue_depth.load(Ordering::Relaxed);
        if pages == 0 && depth == 0 {
            return Ok(());
        }
        if Instant::now() >= deadline {
            return Err(format!(
                "gauges stuck: kv_pages_in_use={pages} queue_depth={depth}"
            ));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// One full seeded schedule against one server. Returns a description
/// of the first violated invariant.
fn run_schedule(seed: u64) -> Result<(), String> {
    let name = format!("chaos-{seed}");
    // the unfaulted reference: same weights, no harness armed
    let clean = start(&name);
    let reference = {
        let rx = submit(&clean, 0)?;
        match drain_terminal(&rx)? {
            Event::Done(r) => r.tokens,
            ev => return Err(format!("clean server errored: {ev:?}")),
        }
    };
    clean.shutdown();

    let srv = start(&name);
    let plan = Arc::new(FaultPlan::seeded(seed, 0.02, 0.01, 0.01, 2));
    let guard = fault::arm_guard(&name, plan.clone());
    let rxs: Vec<mpsc::Receiver<Event>> = (0..REQUESTS)
        .filter_map(|i| submit(&srv, i).ok())
        .collect();
    if rxs.is_empty() {
        return Err("every submission refused".into());
    }
    let mut served = 0usize;
    let mut errored = 0usize;
    for (i, rx) in rxs.iter().enumerate() {
        match drain_terminal(rx).map_err(|e| format!("request {i}: {e}"))? {
            Event::Done(r) => {
                if r.tokens.len() > 6 {
                    return Err(format!(
                        "request {i} overran max_new: {} tokens",
                        r.tokens.len()
                    ));
                }
                served += 1;
            }
            Event::Error { .. } => errored += 1,
            ev => return Err(format!("request {i}: unexpected {ev:?}")),
        }
    }
    eprintln!(
        "seed {seed}: {served} served, {errored} errored, \
         {} faults injected",
        plan.injected()
    );
    await_quiescent(&srv, &name)?;
    // disarm, then the (possibly restarted) engine must serve the
    // clean server's exact greedy tokens
    drop(guard);
    let rx = submit(&srv, 0)?;
    match drain_terminal(&rx)? {
        Event::Done(r) => {
            if r.tokens != reference {
                return Err(format!(
                    "post-fault output diverged: {:?} != {reference:?}",
                    r.tokens
                ));
            }
        }
        ev => {
            return Err(format!("post-fault request failed: {ev:?}"))
        }
    }
    await_quiescent(&srv, &name)?;
    srv.shutdown();
    Ok(())
}

#[test]
fn fixed_seed_schedules_uphold_invariants() {
    for seed in FIXED_SEEDS {
        if let Err(e) = run_schedule(seed) {
            panic!("seed {seed}: {e} (reproduce: CHAOS_SEED={seed})");
        }
    }
}

/// Heavier panic pressure on a single schedule — every second step
/// checkpoint panics until the queue drains, exercising back-to-back
/// supervisor restarts.
#[test]
fn panic_storm_still_terminates_every_request() {
    let name = "chaos-storm";
    let srv = start(name);
    let plan = Arc::new(
        FaultPlan::new()
            .panic_at(fault::CP_STEP, 1)
            .panic_at(fault::CP_STEP, 3)
            .panic_at(fault::CP_STEP, 5),
    );
    let _guard = fault::arm_guard(name, plan);
    let rxs: Vec<_> =
        (0..8).filter_map(|i| submit(&srv, i).ok()).collect();
    for (i, rx) in rxs.iter().enumerate() {
        drain_terminal(rx)
            .unwrap_or_else(|e| panic!("request {i}: {e}"));
    }
    await_quiescent(&srv, name).unwrap();
    srv.shutdown();
}

/// One replica of a 2-wide shard group panicking mid-stream must
/// restart the group as ONE unit: every submitted request still gets
/// exactly one terminal event, the shared gauges return to zero, and
/// the respawned group serves bit-identical greedy output.
#[test]
fn replica_shard_panic_storm_terminates_every_request() {
    let name = "chaos-shardstorm";
    // unfaulted single-engine reference over the same weights
    let clean = start(name);
    let reference = {
        let rx = submit(&clean, 0).expect("clean admit");
        match drain_terminal(&rx).expect("clean terminal") {
            Event::Done(r) => r.tokens,
            ev => panic!("clean server errored: {ev:?}"),
        }
    };
    clean.shutdown();

    let srv = start_sharded(name, ShardPlan::Replica(2));
    let plan = Arc::new(
        FaultPlan::new()
            .panic_at(fault::CP_STEP, 2)
            .panic_at(fault::CP_STEP, 7),
    );
    let guard = fault::arm_guard(name, plan);
    let rxs: Vec<_> =
        (0..8).filter_map(|i| submit(&srv, i).ok()).collect();
    assert!(!rxs.is_empty(), "every submission refused");
    for (i, rx) in rxs.iter().enumerate() {
        drain_terminal(rx)
            .unwrap_or_else(|e| panic!("request {i}: {e}"));
    }
    drop(guard);
    await_quiescent(&srv, name).unwrap();
    // group respawn is atomic: the surviving width-2 group replays
    // the single-engine reference byte for byte
    let rx = submit(&srv, 0).expect("post-fault admit");
    match drain_terminal(&rx).expect("post-fault terminal") {
        Event::Done(r) => assert_eq!(
            r.tokens, reference,
            "respawned shard group diverged"
        ),
        ev => panic!("post-fault request failed: {ev:?}"),
    }
    srv.shutdown();
}

/// Idle-unload racing wake on a sharded cold entry: with a 40 ms idle
/// budget and bursts timed to land while the group is unloading (or
/// just unloaded), every request must be served — admission bumps
/// `queue_depth` before sending, so a request can wake the re-parked
/// supervisor but never be stranded — and every burst replays the
/// first one byte for byte.
#[test]
fn sharded_cold_entry_survives_unload_wake_races() {
    let name = "chaos-shardwake";
    let m = random_model_sized(model_seed_for(name), 2, 16, 2, 40, 64, 16);
    let path = std::env::temp_dir().join("chaos_shardwake.mosaic");
    mosaic::deploy::export_model(&m, &path).expect("export");
    let mut reg = ModelRegistry::new();
    reg.register_cold_sharded(name, &path, ShardPlan::Replica(2))
        .expect("register cold sharded");
    let cfg = ServeConfig {
        max_batch: 2,
        max_queue: 64,
        default_model: Some(name.to_string()),
        max_restarts: 10_000,
        restart_backoff_ms: 1,
        idle_ms: Some(40),
        ..Default::default()
    };
    let srv = Server::start_registry(reg, cfg, 0).expect("start server");
    // first burst doubles as the reference
    let reference: Vec<Vec<u16>> = (0..4)
        .map(|i| {
            let rx = submit(&srv, i).expect("admit");
            match drain_terminal(&rx).expect("terminal") {
                Event::Done(r) => r.tokens,
                ev => panic!("reference request {i} failed: {ev:?}"),
            }
        })
        .collect();
    for cycle in 0..6usize {
        // varied phase: sometimes mid-unload, sometimes just unloaded,
        // sometimes still hot
        std::thread::sleep(Duration::from_millis(25 + 13 * cycle as u64));
        let rxs: Vec<_> = (0..4)
            .map(|i| submit(&srv, i).expect("admit in race window"))
            .collect();
        for (i, rx) in rxs.iter().enumerate() {
            match drain_terminal(rx)
                .unwrap_or_else(|e| panic!("cycle {cycle} req {i}: {e}"))
            {
                Event::Done(r) => assert_eq!(
                    r.tokens, reference[i],
                    "cycle {cycle} request {i} diverged"
                ),
                ev => panic!("cycle {cycle} request {i}: {ev:?}"),
            }
        }
    }
    await_quiescent(&srv, name).unwrap();
    srv.shutdown();
    let _ = std::fs::remove_file(&path);
}

/// Shutdown racing a cold-engine wake: the `lifecycle.wake` stall
/// holds the supervisor mid-spawn (artifact load in progress) while
/// `Server::shutdown` flips the stop flag. Whichever side wins the
/// race, every admitted request must still get exactly one terminal
/// event — a served reply if the wake completed, a typed shutdown
/// error if it did not — and shutdown must join every thread.
#[test]
fn shutdown_during_cold_wake_terminates_every_request() {
    let name = "chaos-coldwake";
    let m = random_model_sized(model_seed_for(name), 2, 16, 2, 40, 64, 16);
    let path = std::env::temp_dir().join("chaos_coldwake.mosaic");
    mosaic::deploy::export_model(&m, &path).expect("export");
    let mut reg = ModelRegistry::new();
    reg.register_cold(name, &path).expect("register cold");
    let cfg = ServeConfig {
        max_batch: 2,
        max_queue: 64,
        default_model: Some(name.to_string()),
        max_restarts: 10_000,
        restart_backoff_ms: 1,
        ..Default::default()
    };
    let srv = Server::start_registry(reg, cfg, 0).expect("start server");
    // hold every wake inside the artifact load for 150 ms — long
    // enough that the shutdown below lands mid-spawn
    let plan = Arc::new(
        FaultPlan::new().stall_every(fault::CP_LIFECYCLE_WAKE, 150),
    );
    let _guard = fault::arm_guard(name, plan);
    let rxs: Vec<_> =
        (0..6).filter_map(|i| submit(&srv, i).ok()).collect();
    assert!(!rxs.is_empty(), "every submission refused");
    // the first admission has already CASed the entry Cold→Waking;
    // shutdown now races the stalled spawn
    srv.shutdown();
    for (i, rx) in rxs.iter().enumerate() {
        drain_terminal(rx)
            .unwrap_or_else(|e| panic!("request {i}: {e}"));
    }
    let _ = std::fs::remove_file(&path);
}

/// One env-seeded exploratory schedule per run. The seed prints up
/// front so a CI failure is reproducible: `CHAOS_SEED=<seed> make
/// chaos`.
#[test]
fn exploratory_seed_from_env() {
    let seed = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or_else(|| {
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos() as u64 | 1)
                .unwrap_or(1)
        });
    eprintln!("chaos exploratory seed: {seed}");
    if let Err(e) = run_schedule(seed) {
        panic!("CHAOS_SEED={seed}: {e}");
    }
}
