//! Batched-decode parity and the one-weight-pass invariant.
//!
//! `DecodeBatch` must produce the same logits as the single-sequence
//! `decode_step` oracle — on dense AND `compact()`ed (f16/CSR) models,
//! with ragged positions (sequences admitted mid-flight, retired
//! early) — and every batched step must make exactly one storage-kernel
//! pass per projection per layer regardless of batch width.

use mosaic::model::weights::testutil::random_model;
use mosaic::model::{
    decode_step, prefill_into, DecodeBatch, DecodeState, ModelWeights,
};
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::tensor::storage::weight_passes;

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!((x - y).abs() < 1e-4, "{what}[{i}]: {x} vs {y}");
    }
}

/// Per-token logits oracle: replay `fed` through the single-sequence
/// decode path.
fn replay_single(m: &ModelWeights, fed: &[u16]) -> Vec<Vec<f32>> {
    let mut st = DecodeState::new(m, fed.len());
    fed.iter()
        .map(|&t| decode_step(m, &mut st, t).to_vec())
        .collect()
}

/// Ragged continuous-batching scenario: A prefills first, B is admitted
/// mid-flight, C is admitted later via bounded prefill chunks, A
/// retires early. Every logit row the batch produces must match the
/// single-sequence oracle for that sequence.
fn parity_scenario(m: &ModelWeights) {
    let mut batch = DecodeBatch::new(m, 3, 32);

    let prompt_a: Vec<u16> = vec![1, 5, 9, 3, 2];
    let mut fed_a = prompt_a.clone();
    let a = batch.admit(32).unwrap();
    let la = prefill_into(m, &mut batch, a, &prompt_a).to_vec();

    // step A alone
    let s1 = batch.step(m, &[(a, 7)]).row(0).to_vec();
    fed_a.push(7);

    // admit B mid-flight
    let prompt_b: Vec<u16> = vec![4, 8];
    let mut fed_b = prompt_b.clone();
    let b = batch.admit(32).unwrap();
    let lb = prefill_into(m, &mut batch, b, &prompt_b).to_vec();

    // step A and B together
    let got = batch.step(m, &[(a, 11), (b, 6)]);
    let (s2a, s2b) = (got.row(0).to_vec(), got.row(1).to_vec());
    fed_a.push(11);
    fed_b.push(6);

    // admit C, prefilled in explicitly bounded chunks
    let prompt_c: Vec<u16> = vec![2, 9, 4, 7, 1, 6, 3];
    let mut fed_c = prompt_c.clone();
    let c = batch.admit(32).unwrap();
    batch.prefill_chunk(m, c, &prompt_c[..3], false);
    let lc = batch.prefill_chunk(m, c, &prompt_c[3..], true).to_vec();

    // full-width step
    let got = batch.step(m, &[(a, 1), (b, 2), (c, 5)]);
    let (s3a, s3b, s3c) =
        (got.row(0).to_vec(), got.row(1).to_vec(), got.row(2).to_vec());
    fed_a.push(1);
    fed_b.push(2);
    fed_c.push(5);

    // retire A early: C (last) slides into index 0, B stays at 1
    batch.retire(a);
    let got = batch.step(m, &[(0, 9), (1, 13)]);
    let (s4c, s4b) = (got.row(0).to_vec(), got.row(1).to_vec());
    fed_c.push(9);
    fed_b.push(13);

    // oracle comparison at every position we observed logits for
    let ra = replay_single(m, &fed_a);
    assert_close(&la, &ra[prompt_a.len() - 1], "A prefill");
    assert_close(&s1, &ra[prompt_a.len()], "A step1");
    assert_close(&s2a, &ra[prompt_a.len() + 1], "A step2");
    assert_close(&s3a, &ra[prompt_a.len() + 2], "A step3");

    let rb = replay_single(m, &fed_b);
    assert_close(&lb, &rb[prompt_b.len() - 1], "B prefill");
    assert_close(&s2b, &rb[prompt_b.len()], "B step2");
    assert_close(&s3b, &rb[prompt_b.len() + 1], "B step3");
    assert_close(&s4b, &rb[prompt_b.len() + 2], "B step4");

    let rc = replay_single(m, &fed_c);
    assert_close(&lc, &rc[prompt_c.len() - 1], "C prefill");
    assert_close(&s3c, &rc[prompt_c.len()], "C step3");
    assert_close(&s4c, &rc[prompt_c.len() + 1], "C step4");
}

#[test]
fn batched_matches_single_dense() {
    let m = random_model(31);
    parity_scenario(&m);
}

#[test]
fn batched_matches_single_sealed() {
    let mut m = random_model(32);
    // mask 70% of every projection so compact() picks CSR/f16 storage
    for l in m.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    m.compact();
    assert!(m.is_compacted());
    parity_scenario(&m);
}

#[test]
fn fused_step_parity_and_single_pass() {
    let m = random_model(35);
    let mut batch = DecodeBatch::new(&m, 2, 32);
    let a = batch.admit(32).unwrap();
    prefill_into(&m, &mut batch, a, &[1, 5, 9]);
    let b = batch.admit(32).unwrap();
    let chunk: Vec<u16> = vec![4, 8, 2];
    // A decodes token 7 while B prefills its whole prompt — still ONE
    // storage pass per projection for the combined work
    let before = weight_passes();
    let logits = batch.step_fused(&m, &[(a, 7)], &[(b, &chunk, true)]);
    let got_a = logits.row(0).to_vec();
    let got_b = logits.row(1).to_vec();
    assert_eq!(
        weight_passes() - before,
        (m.cfg.n_layers * 7) as u64,
        "decode + admission prefill must share one weight pass"
    );
    assert_eq!((batch.pos(a), batch.pos(b)), (4, 3));
    let ra = replay_single(&m, &[1, 5, 9, 7]);
    assert_close(&got_a, &ra[3], "A fused decode");
    let rb = replay_single(&m, &chunk);
    assert_close(&got_b, &rb[2], "B fused prefill");
}

#[test]
fn one_weight_pass_per_projection_per_step() {
    let m = random_model(33);
    let passes_per_step = (m.cfg.n_layers * 7) as u64;
    let mut batch = DecodeBatch::new(&m, 4, 16);
    for si in 0..4usize {
        let s = batch.admit(16).unwrap();
        assert_eq!(s, si);
        prefill_into(&m, &mut batch, s, &[1, 2 + si as u16]);
    }
    // weight_passes is thread-local, so concurrent tests in this
    // binary cannot perturb the deltas measured here
    let before = weight_passes();
    batch.step(&m, &[(0, 3), (1, 4), (2, 5), (3, 6)]);
    assert_eq!(
        weight_passes() - before,
        passes_per_step,
        "width-4 step must make exactly one storage pass per projection"
    );
    let before = weight_passes();
    batch.step(&m, &[(0, 7)]);
    assert_eq!(
        weight_passes() - before,
        passes_per_step,
        "per-step weight traffic must be independent of batch width"
    );
}

#[test]
fn prefill_chunk_boundary_parity() {
    // the chunk loop bounds the speculative verify path reuses: prompts
    // of length exactly PREFILL_CHUNK, PREFILL_CHUNK±1 and
    // 2*PREFILL_CHUNK must produce logits BIT-IDENTICAL to one
    // unchunked fused pass over the whole prompt (same kernels, same
    // summation order — only the row grouping differs), and close to
    // the forward_full oracle
    use mosaic::model::{forward_full, PREFILL_CHUNK};
    let m = random_model(36);
    for len in [
        PREFILL_CHUNK - 1,
        PREFILL_CHUNK,
        PREFILL_CHUNK + 1,
        2 * PREFILL_CHUNK,
    ] {
        let prompt: Vec<u16> =
            (0..len).map(|i| (3 + 5 * i) as u16 % 60).collect();
        let cap = len + 1;
        // chunked: the production prefill loop
        let mut chunked = DecodeBatch::new(&m, 1, cap);
        let sc = chunked.admit(cap).unwrap();
        let got =
            prefill_into(&m, &mut chunked, sc, &prompt).to_vec();
        assert_eq!(chunked.pos(sc), len, "len {len}: cursor");
        // unchunked: the whole prompt as ONE fused pass (row budget
        // sized to fit), logits at the last row
        let mut whole = DecodeBatch::with_rows(&m, 1, cap, len);
        let sw = whole.admit(cap).unwrap();
        let want = whole
            .step_fused(&m, &[], &[(sw, &prompt, true)])
            .row(0)
            .to_vec();
        assert_eq!(
            got, want,
            "len {len}: chunk boundaries must not change a single bit"
        );
        // and both agree with the full-sequence engine oracle
        let full = forward_full(&m, &prompt);
        assert_close(&got, full.row(len - 1), "chunked vs forward_full");
        // the caches line up too: the next decode step matches the
        // oracle continuation
        let next_c = chunked.step(&m, &[(sc, 9)]).row(0).to_vec();
        let next_w = whole.step(&m, &[(sw, 9)]).row(0).to_vec();
        assert_eq!(next_c, next_w, "len {len}: post-prefill step");
    }
}

#[test]
fn prefill_chunk_counts_one_pass_per_projection() {
    let m = random_model(34);
    let mut batch = DecodeBatch::new(&m, 1, 64);
    let si = batch.admit(64).unwrap();
    let before = weight_passes();
    // 40 tokens = 2 chunks → 2 × (layers × 7) passes, not 40 ×
    let prompt: Vec<u16> = (0..40).map(|i| (i % 60) as u16).collect();
    prefill_into(&m, &mut batch, si, &prompt);
    assert_eq!(
        weight_passes() - before,
        2 * (m.cfg.n_layers * 7) as u64,
        "chunked prefill streams weights once per chunk, not per token"
    );
}
