//! Serving protocol v1 end-to-end over real TCP: v0 wire
//! compatibility, typed-client round-trips, registry routing, and
//! streaming framing. Complements the in-module tests in
//! `serve/mod.rs` (engine-level determinism, stop conditions, vocab
//! admission) by exercising the public surface the way an external
//! client would.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use mosaic::model::weights::testutil::{random_model, random_model_sized};
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::{
    ModelRegistry, SamplingParams, ServeConfig, Server,
};
use mosaic::util::json::Json;

/// v0 request → byte-level v0 reply: exactly the five pre-v1 keys in
/// the frozen serialization order, greedy tokens, fully deterministic.
/// (The serializer's exact bytes are frozen in
/// `protocol::tests::v0_reply_bytes_are_frozen`; this covers the wire
/// path end-to-end.)
#[test]
fn v0_wire_compat_is_exact() {
    let m = random_model(501);
    let srv = Server::start(m, ServeConfig::default(), 0).unwrap();
    let mut stream = TcpStream::connect(srv.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut runs: Vec<Vec<u16>> = Vec::new();
    for _ in 0..2 {
        stream
            .write_all(b"{\"prompt\": [1, 4, 9], \"max_new\": 3}\n")
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        // exactly the v0 key set — nothing leaked from v1
        let keys: Vec<&str> = j
            .as_obj()
            .unwrap()
            .keys()
            .map(|k| k.as_str())
            .collect();
        assert_eq!(
            keys,
            vec!["decode_ms", "id", "prefill_ms", "queue_ms", "tokens"],
            "{line}"
        );
        runs.push(
            j.get("tokens")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_usize().unwrap() as u16)
                .collect(),
        );
    }
    assert!(!runs[0].is_empty());
    assert_eq!(runs[0], runs[1], "greedy serving must be deterministic");
    srv.shutdown();
}

/// The typed client against a two-model registry: routing, sampling
/// reproducibility, and stop conditions over real TCP.
#[test]
fn client_routes_samples_and_stops() {
    let mut reg = ModelRegistry::new();
    reg.register("a", random_model_sized(502, 2, 16, 2, 40, 64, 16))
        .unwrap();
    reg.register("b", random_model_sized(503, 2, 16, 2, 40, 64, 16))
        .unwrap();
    let srv = Server::start_registry(
        reg,
        ServeConfig {
            default_model: Some("a".into()),
            ..Default::default()
        },
        0,
    )
    .unwrap();
    let mut c = Client::connect(srv.addr).unwrap();
    let prompt = [1u16, 9, 4];
    let ra = c
        .generate(&GenRequest::greedy(&prompt).max_new(12).model("a"))
        .unwrap();
    let rb = c
        .generate(&GenRequest::greedy(&prompt).max_new(12).model("b"))
        .unwrap();
    assert_eq!(ra.model.as_deref(), Some("a"));
    assert_eq!(rb.model.as_deref(), Some("b"));
    assert_ne!(ra.tokens, rb.tokens, "different weights, same tokens?");
    // default routing (v1 via explicit sampling) goes to "a"
    let sp = SamplingParams {
        temperature: 0.8,
        top_k: 8,
        seed: 7,
        ..Default::default()
    };
    let s1 = c
        .generate(&GenRequest::greedy(&prompt).max_new(10).sampled(sp))
        .unwrap();
    let s2 = c
        .generate(&GenRequest::greedy(&prompt).max_new(10).sampled(sp))
        .unwrap();
    assert_eq!(s1.model.as_deref(), Some("a"));
    assert_eq!(s1.tokens, s2.tokens, "seeded sampling must reproduce");
    // stop on the first greedy token
    let stopped = c
        .generate(
            &GenRequest::greedy(&prompt)
                .max_new(12)
                .model("a")
                .stop_tokens(&[ra.tokens[0]]),
        )
        .unwrap();
    assert_eq!(stopped.tokens, vec![ra.tokens[0]]);
    assert_eq!(stopped.finish_reason.as_deref(), Some("stop"));
    // unknown model comes back as a server error, not a hang
    let err = c
        .generate(&GenRequest::greedy(&prompt).model("nope"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown model"), "{err}");
    // ... and the connection stays usable afterwards
    let again = c
        .generate(&GenRequest::greedy(&prompt).max_new(2))
        .unwrap();
    assert!(!again.tokens.is_empty());
    srv.shutdown();
}

/// Streaming over the wire: per-token event lines, ascending indices,
/// and a final summary that mirrors them (Client validates framing
/// internally; the raw-socket pass checks the actual line shapes).
#[test]
fn streaming_framing_on_the_wire() {
    let m = random_model(504);
    let srv = Server::start(m, ServeConfig::default(), 0).unwrap();
    let mut stream = TcpStream::connect(srv.addr).unwrap();
    stream
        .write_all(
            b"{\"prompt\": [1, 5, 9], \"max_new\": 5, \"stream\": true}\n",
        )
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut tokens = Vec::new();
    let done = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        match j.get("event").and_then(|e| e.as_str()) {
            Some("token") => {
                assert_eq!(
                    j.get("index").unwrap().as_usize().unwrap(),
                    tokens.len(),
                    "{line}"
                );
                tokens.push(
                    j.get("token").unwrap().as_usize().unwrap() as u16,
                );
            }
            Some("done") => break j,
            other => panic!("unexpected event {other:?}: {line}"),
        }
    };
    let final_tokens: Vec<u16> = done
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u16)
        .collect();
    assert_eq!(tokens, final_tokens, "stream must mirror the summary");
    assert!(done.get("finish_reason").is_some());
    assert!(done.get("queue_ms").is_some());
    assert!(done.get("prefill_ms").is_some());
    assert!(done.get("decode_ms").is_some());
    // the same connection then handles a typed streaming request
    drop(reader);
    drop(stream);
    let mut c = Client::connect(srv.addr).unwrap();
    let mut seen = 0usize;
    let r = c
        .generate_with(
            &GenRequest::greedy(&[1, 5, 9]).max_new(5).streaming(),
            |_, _| seen += 1,
        )
        .unwrap();
    assert_eq!(seen, r.tokens.len());
    assert_eq!(r.tokens, final_tokens, "greedy stream is deterministic");
    srv.shutdown();
}

/// Malformed/boundary corpus over the wire: every bad line gets an
/// error reply and the connection keeps serving.
#[test]
fn wire_errors_keep_connection_alive() {
    let m = random_model(505);
    let srv = Server::start(m, ServeConfig::default(), 0).unwrap();
    let mut stream = TcpStream::connect(srv.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let bad: &[&str] = &[
        "garbage",
        "{\"max_new\": 3}",
        "{\"prompt\": []}",
        "{\"prompt\": [1], \"temperature\": -2}",
        "{\"prompt\": [1], \"top_k\": 65537}",
        "{\"prompt\": [1], \"top_p\": 2}",
        "{\"prompt\": [1], \"model\": \"ghost\"}",
        "{\"prompt\": [63000], \"max_new\": 2}",
        "{\"prompt\": [1], \"v\": 9}",
        "{\"prompt\": [1], \"spec\": {\"k\": 99}}",
        // no pair is registered on this server at all
        "{\"prompt\": [1], \"spec\": {}}",
    ];
    for req in bad {
        stream.write_all(format!("{req}\n").as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert!(
            j.get("error").is_some(),
            "expected error for {req}: {line}"
        );
    }
    // still alive: a good request succeeds on the same connection —
    // and top_k 0 (= off) is now VALID on the wire, matching the
    // in-process validator (regression: it used to be rejected while
    // the error text claimed the range started at 1)
    stream
        .write_all(
            b"{\"prompt\": [1, 4], \"max_new\": 2, \"top_k\": 0, \
               \"seed\": 3}\n",
        )
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert!(j.get("tokens").is_some(), "{line}");
    srv.shutdown();
}

/// Satellite regression, wire-level: a request whose prompt + max_new
/// cannot fit in the context window must be refused with a protocol
/// error — the old admission clamped the prompt to
/// `max_ctx - max_new`, which for `max_new >= max_ctx` truncated it to
/// ZERO tokens and served garbage from an empty prefix.
#[test]
fn wire_rejects_prompt_plus_max_new_over_context() {
    let m = random_model(506);
    let srv = Server::start(
        m,
        ServeConfig { max_ctx: 64, ..Default::default() },
        0,
    )
    .unwrap();
    let mut stream = TcpStream::connect(srv.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // max_new == max_ctx: the exact pre-fix garbage-serving shape
    stream
        .write_all(b"{\"prompt\": [1, 2, 3], \"max_new\": 64}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let err = j.get("error").expect("must be refused").as_str().unwrap();
    assert!(err.contains("exceeds context"), "{line}");
    // the boundary request on the same connection still serves
    stream
        .write_all(b"{\"prompt\": [1, 2, 3], \"max_new\": 61}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert!(j.get("tokens").is_some(), "{line}");
    srv.shutdown();
}

/// Weighted routes on the wire: a v1 request addressing the logical
/// name gets `"route"` echoed back and `"model"` naming the backend
/// that actually served it; direct addressing stays untagged; and a
/// v0 request on the same (routed) server keeps the exact frozen
/// five-key reply — routing must not leak into the v0 surface.
#[test]
fn routed_requests_on_the_wire() {
    use mosaic::serve::router::parse_route;
    let mut reg = ModelRegistry::new();
    reg.register("dense", random_model_sized(508, 2, 16, 2, 40, 64, 16))
        .unwrap();
    reg.register("canary", random_model_sized(509, 2, 16, 2, 40, 64, 16))
        .unwrap();
    let srv = Server::start_registry(
        reg,
        ServeConfig {
            default_model: Some("dense".into()),
            routes: vec![parse_route("chat=dense:70,canary:30").unwrap()],
            route_seed: 7,
            ..Default::default()
        },
        0,
    )
    .unwrap();
    let mut c = Client::connect(srv.addr).unwrap();
    let prompt = [1u16, 9, 4];
    for _ in 0..8 {
        let r = c
            .generate(&GenRequest::greedy(&prompt).max_new(4).model("chat"))
            .unwrap();
        assert_eq!(r.route.as_deref(), Some("chat"));
        let backend = r.model.as_deref().unwrap();
        assert!(
            backend == "dense" || backend == "canary",
            "route must resolve to a real backend, got {backend:?}"
        );
    }
    // direct addressing bypasses the table — no route tag
    let r = c
        .generate(&GenRequest::greedy(&prompt).max_new(4).model("dense"))
        .unwrap();
    assert_eq!(r.route, None);
    // v0 on a routed server: exactly the frozen five keys, no leak
    let mut stream = TcpStream::connect(srv.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream
        .write_all(b"{\"prompt\": [1, 4, 9], \"max_new\": 3}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let keys: Vec<&str> =
        j.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
    assert_eq!(
        keys,
        vec!["decode_ms", "id", "prefill_ms", "queue_ms", "tokens"],
        "{line}"
    );
    srv.shutdown();
}

/// Speculative pair over real TCP through the typed client: routed by
/// pair name or via the "spec" field, byte-identical to the dense
/// reply, acceptance counters on the wire.
#[test]
fn client_drives_speculative_pair() {
    use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
    let dense = random_model_sized(507, 2, 16, 2, 40, 64, 16);
    let mut draft = dense.clone();
    for l in draft.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    draft.compact();
    let mut reg = ModelRegistry::new();
    reg.register("dense", dense).unwrap();
    reg.register("d70", draft).unwrap();
    reg.register_spec("pair", "dense", "d70", 4).unwrap();
    let srv =
        Server::start_registry(reg, ServeConfig::default(), 0).unwrap();
    let mut c = Client::connect(srv.addr).unwrap();
    let prompt = [1u16, 9, 4, 7];
    let base = c
        .generate(&GenRequest::greedy(&prompt).max_new(12).model("dense"))
        .unwrap();
    assert!(base.spec.is_none(), "plain reply has no spec counters");
    // by pair name
    let by_name = c
        .generate(&GenRequest::greedy(&prompt).max_new(12).model("pair"))
        .unwrap();
    assert_eq!(by_name.tokens, base.tokens, "wire-level bit-identity");
    assert_eq!(by_name.model.as_deref(), Some("pair"));
    let u = by_name.spec.expect("pair reply carries spec counters");
    assert!(u.accepted <= u.drafted, "{u:?}");
    // via the "spec" request field on the target model
    let by_field = c
        .generate(
            &GenRequest::greedy(&prompt)
                .max_new(12)
                .model("dense")
                .speculative(Some("d70"), Some(2)),
        )
        .unwrap();
    assert_eq!(by_field.tokens, base.tokens);
    assert_eq!(by_field.model.as_deref(), Some("pair"));
    // a wrong draft name is an admission error, connection survives
    let err = c
        .generate(
            &GenRequest::greedy(&prompt)
                .model("dense")
                .speculative(Some("ghost"), None),
        )
        .unwrap_err()
        .to_string();
    assert!(err.contains("no speculative pair"), "{err}");
    let again = c
        .generate(&GenRequest::greedy(&prompt).max_new(2))
        .unwrap();
    assert!(!again.tokens.is_empty());
    srv.shutdown();
}
