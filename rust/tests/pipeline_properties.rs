//! Property tests (hand-rolled seeded sweeps — proptest is not in this
//! image) over the planner / pruner / quantizer invariants, independent
//! of artifacts.

use mosaic::model::config::Proj;
use mosaic::model::weights::testutil::random_model;
use mosaic::prune::composite::{split_plan, CompositeOpts};
use mosaic::prune::planner::{plan, MAX_TARGET};
use mosaic::prune::{
    prune_composite, prune_structured, prune_unstructured, Metric,
    Uniformity,
};
use mosaic::quant::{quantize_model, QuantConfig};
use mosaic::rank::{normalize_rank, GlobalRank};
use mosaic::util::rng::Pcg32;

fn rand_rank(rng: &mut Pcg32, layers: usize) -> GlobalRank {
    let mut rank: Vec<Vec<f64>> = (0..layers)
        .map(|_| (0..7).map(|_| rng.f64() * 3.0).collect())
        .collect();
    normalize_rank(&mut rank);
    GlobalRank { rank, alpha: 5.0 }
}

#[test]
fn planner_invariants_sweep() {
    let mut rng = Pcg32::seeded(0x50 + 1);
    for trial in 0..300 {
        let layers = 1 + rng.below(16);
        let g = rand_rank(&mut rng, layers);
        let p = rng.f64() * 0.93;
        for u in [Uniformity::Global, Uniformity::Layer,
                  Uniformity::Projection] {
            let pl = plan(&g, p, u);
            // I1: mean == p
            assert!(
                (pl.mean_target() - p).abs() < 2e-3,
                "trial {trial}: mean {} != {p}",
                pl.mean_target()
            );
            // I2: bounds
            for t in pl.targets.iter().flatten() {
                assert!((0.0..=MAX_TARGET + 1e-12).contains(t));
            }
            // I3: shape
            assert_eq!(pl.targets.len(), layers);
        }
    }
}

#[test]
fn composite_split_preserves_live_fraction() {
    let mut rng = Pcg32::seeded(77);
    for _ in 0..200 {
        let layers = 1 + rng.below(8);
        let g = rand_rank(&mut rng, layers);
        let p = rng.f64() * 0.9;
        let share = rng.f64();
        let pl = plan(&g, p, Uniformity::Projection);
        let (st, un) = split_plan(&pl, share);
        for ((a, b), t) in st
            .targets
            .iter()
            .flatten()
            .zip(un.targets.iter().flatten())
            .zip(pl.targets.iter().flatten())
        {
            let live = (1.0 - a) * (1.0 - b);
            assert!(
                (live - (1.0 - t)).abs() < 1e-9,
                "live {live} target {t}"
            );
        }
    }
}

#[test]
fn unstructured_hits_requested_sparsity_sweep() {
    let mut rng = Pcg32::seeded(99);
    for trial in 0..20 {
        let mut m = random_model(1000 + trial);
        let g = rand_rank(&mut rng, m.cfg.n_layers);
        let p = 0.1 + 0.8 * rng.f64();
        let pl = plan(&g, p, Uniformity::Projection);
        prune_unstructured(&mut m, &pl, None, Metric::Magnitude);
        let s = mosaic::prune::unstructured::projection_sparsity(&m);
        assert!((s - p).abs() < 0.03, "trial {trial}: {s} vs {p}");
    }
}

#[test]
fn structured_never_empties_and_stays_consistent() {
    let mut rng = Pcg32::seeded(123);
    for trial in 0..20 {
        let mut m = random_model(2000 + trial);
        let g = rand_rank(&mut rng, m.cfg.n_layers);
        let p = rng.f64() * 0.93;
        let pl = plan(&g, p, Uniformity::Projection);
        prune_structured(&mut m, &pl);
        for l in &m.layers {
            let hk = l.kept_heads.len();
            let c = l.kept_channels.len();
            assert!(hk >= 1 && c >= 1);
            assert_eq!(l.proj(Proj::Q).cols(), hk * m.cfg.head_dim);
            assert_eq!(l.proj(Proj::K).cols(), hk * m.cfg.head_dim);
            assert_eq!(l.proj(Proj::V).cols(), hk * m.cfg.head_dim);
            assert_eq!(l.proj(Proj::O).rows(), hk * m.cfg.head_dim);
            assert_eq!(l.proj(Proj::Gate).cols(), c);
            assert_eq!(l.proj(Proj::Up).cols(), c);
            assert_eq!(l.proj(Proj::Down).rows(), c);
            // kept lists strictly increasing (valid index maps)
            assert!(l.kept_heads.windows(2).all(|w| w[0] < w[1]));
            assert!(l.kept_channels.windows(2).all(|w| w[0] < w[1]));
        }
        // pruned model produces finite output
        let out = mosaic::model::engine::forward_full(&m, &[1, 2, 3]);
        assert!(out.data.iter().all(|x| x.is_finite()), "trial {trial}");
    }
}

#[test]
fn composite_monotone_bytes_in_share() {
    // more structural share => smaller stored model
    let mut prev = usize::MAX;
    for share in [0.0, 0.25, 0.5, 0.75] {
        let mut m = random_model(42);
        let g = GlobalRank {
            rank: vec![vec![1.0; 7]; m.cfg.n_layers],
            alpha: 5.0,
        };
        let pl = plan(&g, 0.7, Uniformity::Global);
        prune_composite(
            &mut m,
            &pl,
            None,
            None,
            CompositeOpts { struct_share: share, use_obs: false },
        );
        assert!(
            m.model_bytes() <= prev,
            "share {share}: {} > {prev}",
            m.model_bytes()
        );
        prev = m.model_bytes();
    }
}

#[test]
fn storage_roundtrip_logits_within_f16_tolerance() {
    // Property (encode→load→decode parity): a model round-tripped
    // through each ProjStorage variant — sealed in memory AND shipped
    // through the deploy byte format — produces logits within f16
    // tolerance of the dense-f32 path, across random sparsity levels.
    use mosaic::model::engine::forward_full;
    use mosaic::tensor::ProjStorage;
    let mut rng = Pcg32::seeded(451);
    for trial in 0u64..6 {
        let mut m = random_model(4000 + trial);
        let p = 0.9 * rng.f64();
        let g = rand_rank(&mut rng, m.cfg.n_layers);
        let pl = plan(&g, p, Uniformity::Projection);
        prune_unstructured(&mut m, &pl, None, Metric::Magnitude);
        let toks: Vec<u16> = (0..8)
            .map(|i| ((i * 13 + trial as usize) % 60 + 2) as u16)
            .collect();
        let dense = forward_full(&m, &toks);
        let close = |name: &str, got: &mosaic::tensor::Tensor| {
            assert_eq!(got.shape, dense.shape);
            for (a, b) in dense.data.iter().zip(got.data.iter()) {
                assert!(
                    (a - b).abs() < 5e-2 * (1.0 + a.abs()),
                    "trial {trial} p={p:.2} {name}: {a} vs {b}"
                );
            }
        };
        // each variant forced explicitly
        type SealFn = fn(&mosaic::tensor::Tensor) -> ProjStorage;
        let variants: [(&str, SealFn); 2] = [
            ("f16", ProjStorage::seal_f16),
            ("csr", ProjStorage::seal_csr),
        ];
        for (name, seal) in variants {
            let mut sealed = m.clone();
            for l in sealed.layers.iter_mut() {
                for s in l.projs.iter_mut() {
                    let v = seal(s.dense());
                    *s = v;
                }
            }
            close(name, &forward_full(&sealed, &toks));
        }
        // auto-chosen backends (compact) …
        let mut mc = m.clone();
        mc.compact();
        assert!(mc.resident_bytes() <= m.resident_bytes());
        close("compact", &forward_full(&mc, &toks));
        // … and the full export→load_encoded byte round trip
        let path = std::env::temp_dir()
            .join(format!("mosaic_prop_rt_{trial}.bin"));
        mosaic::deploy::export_model(&m, &path).unwrap();
        let loaded = mosaic::deploy::load_encoded(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(loaded
            .layers
            .iter()
            .flat_map(|l| l.projs.iter())
            .all(|s| !s.is_dense_f32()));
        close("load_encoded", &forward_full(&loaded, &toks));
    }
}

#[test]
fn produce_composite_targets_and_roundtrip() {
    // Property over the streaming pipeline (satellite of the
    // production-pipeline PR): after `produce` with a composite plan,
    // (a) removed_fraction lands on the plan's p (group rounding is
    // coarse at unit scale), (b) projection sparsity behaves —
    // exactly p for the pure-mask pruner, near the residual
    // unstructured share s_u for the composite — and (c) every sealed
    // projection round-trips through export_model/load_encoded
    // unchanged, byte for byte.
    use mosaic::prune::composite::removed_fraction;
    use mosaic::prune::pipeline::{produce, ProduceOpts, PrunerKind};
    use mosaic::prune::planner::PruningPlan;
    use mosaic::prune::unstructured::projection_sparsity;

    let samples: Vec<Vec<u16>> = (0..3)
        .map(|s| {
            (0..10)
                .map(|i| ((i * 5 + s * 11) % 60 + 2) as u16)
                .collect()
        })
        .collect();
    // p values where group rounding at unit scale (2 heads, 40
    // channels) keeps the structural share realizable
    for (trial, p) in [(0u64, 0.6), (1, 0.65), (2, 0.7)] {
        let m = random_model(6000 + trial);
        let prunable = m.cfg.prunable_params();
        let pl = PruningPlan::uniform(m.cfg.n_layers, p);

        // pure-mask pruner: measured sparsity must hit p tightly
        let rep_mag = produce(
            &m,
            &pl,
            &samples,
            &ProduceOpts::new(PrunerKind::Magnitude).with_workers(4),
        );
        let s = projection_sparsity(&rep_mag.model);
        assert!(
            (s - p).abs() < 0.03,
            "trial {trial} magnitude sparsity {s} vs target {p}"
        );

        // composite plan: removed fraction lands on p; kept-structure
        // sparsity sits near the residual share s_u (the structural
        // step preferentially removes hollowed-out groups, so it may
        // come in under s_u — never far over)
        let rep = produce(
            &m,
            &pl,
            &samples,
            &ProduceOpts::new(PrunerKind::Composite(
                CompositeOpts::default(),
            ))
            .with_workers(4),
        );
        let removed = removed_fraction(&rep.model, prunable);
        assert!(
            (removed - p).abs() < 0.12,
            "trial {trial}: removed {removed} vs target {p}"
        );
        let share = mosaic::prune::composite::DEFAULT_STRUCT_SHARE;
        let s_u = 1.0 - (1.0 - p) / (1.0 - share * p);
        let got = projection_sparsity(&rep.model);
        assert!(
            got < s_u + 0.05 && got > s_u - 0.25,
            "trial {trial}: kept-structure sparsity {got} vs s_u {s_u}"
        );

        // sealed projections round-trip through the deploy format
        // unchanged (f16/CSR bytes are canonical)
        let path = std::env::temp_dir()
            .join(format!("mosaic_produce_rt_{trial}.bin"));
        mosaic::deploy::export_model(&rep.model, &path).unwrap();
        let loaded = mosaic::deploy::load_encoded(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.layers.len(), rep.model.layers.len());
        for (li, (a, b)) in rep
            .model
            .layers
            .iter()
            .zip(loaded.layers.iter())
            .enumerate()
        {
            assert_eq!(a.kept_heads, b.kept_heads, "trial {trial} l{li}");
            assert_eq!(
                a.kept_channels, b.kept_channels,
                "trial {trial} l{li}"
            );
            for (pi, (x, y)) in
                a.projs.iter().zip(b.projs.iter()).enumerate()
            {
                assert!(
                    x == y,
                    "trial {trial} l{li} p{pi}: projection changed \
                     across export/load ({} vs {})",
                    x.encoding_name(),
                    y.encoding_name()
                );
            }
        }
    }
}

#[test]
fn quantizer_error_monotone_in_bits_sweep() {
    for seed in 0..5 {
        let m = random_model(3000 + seed);
        let mut last = f64::MAX;
        for bits in [2u32, 3, 4, 8] {
            let mut q = m.clone();
            let mse = quantize_model(&mut q, None, QuantConfig::new(bits));
            assert!(
                mse < last * 1.001,
                "seed {seed} bits {bits}: {mse} !< {last}"
            );
            last = mse;
        }
    }
}

#[test]
fn json_fuzz_roundtrip() {
    use mosaic::util::json::Json;
    let mut rng = Pcg32::seeded(314);
    fn gen(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.f64() * 2e6).round() / 1000.0 - 1000.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| {
                        let c = b" abc\"\\\n\tXYZ"[rng.below(11)];
                        c as char
                    })
                    .collect::<String>(),
            ),
            4 => Json::Arr(
                (0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect(),
            ),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(5) {
                    o.set(&format!("k{i}"), gen(rng, depth + 1));
                }
                o
            }
        }
    }
    for _ in 0..500 {
        let v = gen(&mut rng, 0);
        let s = v.to_string();
        let v2 = Json::parse(&s).unwrap_or_else(|e| {
            panic!("reparse failed: {e} for {s}")
        });
        assert_eq!(v, v2, "roundtrip mismatch for {s}");
    }
}
