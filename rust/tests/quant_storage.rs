//! Quantized runtime storage end-to-end: tolerance parity against the
//! f32 oracle (error bounded by the per-group scale), bit-identity
//! across batch widths and across SIMD-vs-scalar kernel composition,
//! GPTQ+seal pipeline determinism across worker counts, header-v3
//! export/load byte round-trips (with v2 compatibility covered in
//! `deploy::tests`), and serving a pruned+quantized model over real
//! TCP. Complements the per-primitive property tests in `tensor::simd`
//! and the per-kernel unit tests in `tensor::storage`.

use mosaic::deploy::{self, QuantSpec};
use mosaic::model::engine::{argmax, decode_step, forward_full, DecodeState};
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::prune::pipeline::{produce, ProduceOpts, PrunerKind};
use mosaic::prune::planner::PruningPlan;
use mosaic::prune::{plan, Uniformity};
use mosaic::quant::{quantize_model, QuantConfig};
use mosaic::rank::{normalize_rank, GlobalRank};
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::{ModelRegistry, ServeConfig, Server};
use mosaic::tensor::storage::weight_passes;
use mosaic::tensor::{
    matmul_storage, matvec_storage, simd, CsrVals, ProjStorage, Tensor,
};
use mosaic::util::rng::Pcg32;

fn sparse_tensor(seed: u64, r: usize, c: usize, sparsity: f64) -> Tensor {
    let mut rng = Pcg32::seeded(seed);
    let data = (0..r * c)
        .map(|_| if rng.f64() < sparsity { 0.0 } else { rng.normal() })
        .collect();
    Tensor::new(data, vec![r, c])
}

/// One seal per storage variant (group 8 keeps several scale groups in
/// play at test sizes).
fn all_seals(t: &Tensor, group: usize) -> Vec<ProjStorage> {
    vec![
        ProjStorage::from_dense(t.clone()),
        ProjStorage::seal_f16(t),
        ProjStorage::seal_i8(t, group),
        ProjStorage::seal_i4(t, group),
        ProjStorage::seal_csr(t),
        ProjStorage::seal_csr_i8(t, group),
    ]
}

/// An 80%-magnitude-pruned then i8-quantized model whose projections
/// seal to csr8 — the acceptance-criteria configuration. (Shapes are
/// 32/80-wide: on very narrow projections the per-column f32 scale
/// grid outweighs csr8's 1-byte-per-entry saving and the cost table
/// rightly picks i8 or plain CSR instead.)
fn pruned_quantized_model(seed: u64, group: usize) -> ModelWeights {
    let mut m = random_model_sized(seed, 2, 32, 2, 80, 64, 16);
    for l in m.layers.iter_mut() {
        for p in l.projs.iter_mut() {
            let t = p.dense_mut();
            let sc: Vec<f64> =
                t.data.iter().map(|x| x.abs() as f64).collect();
            mosaic::prune::unstructured::mask_lowest(t, &sc, 0.8);
        }
    }
    quantize_model(&mut m, None, QuantConfig { bits: 8, group });
    m.compact_q(Some(QuantSpec::i8(group)));
    m
}

/// The quantization error of a sealed matvec is bounded per output by
/// half a grid step per contributing weight:
/// |y_q[j] − y[j]| ≤ Σ_k |x_k| · scale[g(k)][j] / 2 (+ float slack).
#[test]
fn quantized_matvec_tracks_f32_oracle_within_group_scale() {
    let (k, n, group) = (48, 33, 16);
    let t = sparse_tensor(11, k, n, 0.5);
    let mut rng = Pcg32::seeded(12);
    let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let mut oracle = vec![0.0f32; n];
    matvec_storage(&x, &ProjStorage::from_dense(t.clone()), &mut oracle);
    for s in [
        ProjStorage::seal_i8(&t, group),
        ProjStorage::seal_i4(&t, group),
        ProjStorage::seal_csr_i8(&t, group),
    ] {
        let (scales, g) = match &s {
            ProjStorage::DenseI8 { scales, group, .. }
            | ProjStorage::GroupedI4 { scales, group, .. }
            | ProjStorage::SparseCsr {
                vals: CsrVals::I8 { scales, group, .. },
                ..
            } => (scales.clone(), *group),
            _ => unreachable!(),
        };
        let mut y = vec![0.0f32; n];
        matvec_storage(&x, &s, &mut y);
        for j in 0..n {
            let tol = (0..k)
                .map(|kk| x[kk].abs() * scales[(kk / g) * n + j] * 0.5)
                .sum::<f32>()
                * 1.001
                + 1e-4;
            assert!(
                (y[j] - oracle[j]).abs() <= tol,
                "{} out[{j}]: {} vs oracle {} (tol {tol})",
                s.encoding_name(),
                y[j],
                oracle[j]
            );
        }
    }
}

/// Widths 1/2/8 through `matmul_storage` must reproduce the width-1
/// decode kernel bit-for-bit, for every storage variant — the batched
/// prefill/decode path may never change logits.
#[test]
fn batch_widths_bit_identical_for_every_backend() {
    let (k, n) = (40, 24);
    let t = sparse_tensor(21, k, n, 0.6);
    let mut rng = Pcg32::seeded(22);
    let xs: Vec<f32> = (0..8 * k).map(|_| rng.normal()).collect();
    for s in all_seals(&t, 8) {
        let rows: Vec<Vec<f32>> = (0..8)
            .map(|b| {
                let mut y = vec![0.0f32; n];
                matvec_storage(&xs[b * k..(b + 1) * k], &s, &mut y);
                y
            })
            .collect();
        for width in [1usize, 2, 8] {
            for start in (0..8).step_by(width) {
                let x = Tensor::new(
                    xs[start * k..(start + width) * k].to_vec(),
                    vec![width, k],
                );
                let out = matmul_storage(&x, &s);
                for b in 0..width {
                    for (got, want) in out.data[b * n..(b + 1) * n]
                        .iter()
                        .zip(rows[start + b].iter())
                    {
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "{} width {width} row {}",
                            s.encoding_name(),
                            start + b
                        );
                    }
                }
            }
        }
    }
}

/// The dispatched kernels (whatever backend `simd::active()` picked on
/// this host) must match a hand-composed `Backend::Scalar` traversal
/// bit-for-bit — the subsystem's core invariant, checked here at the
/// full-matvec level on top of `tensor::simd`'s per-primitive suite.
#[test]
fn active_dispatch_matches_scalar_composition_bitwise() {
    use mosaic::tensor::simd::Backend;
    let (k, n, group) = (32, 17, 8);
    let t = sparse_tensor(31, k, n, 0.4);
    let mut rng = Pcg32::seeded(32);
    let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let sc = Backend::Scalar;
    for s in all_seals(&t, group) {
        let mut got = vec![0.0f32; n];
        matvec_storage(&x, &s, &mut got);
        let mut want = vec![0.0f32; n];
        match &s {
            ProjStorage::DenseF32(t) => {
                for (kk, &xv) in x.iter().enumerate() {
                    if xv != 0.0 {
                        sc.axpy(xv, &t.data[kk * n..][..n], &mut want);
                    }
                }
            }
            ProjStorage::DenseF16 { bits, .. } => {
                for (kk, &xv) in x.iter().enumerate() {
                    if xv != 0.0 {
                        sc.axpy_f16(xv, &bits[kk * n..][..n], &mut want);
                    }
                }
            }
            ProjStorage::DenseI8 { vals, scales, group, .. } => {
                for (kk, &xv) in x.iter().enumerate() {
                    if xv != 0.0 {
                        sc.axpy_i8(
                            xv,
                            &vals[kk * n..][..n],
                            &scales[(kk / group) * n..][..n],
                            &mut want,
                        );
                    }
                }
            }
            ProjStorage::GroupedI4 { packed, scales, group, .. } => {
                let stride = n.div_ceil(2);
                for (kk, &xv) in x.iter().enumerate() {
                    if xv != 0.0 {
                        sc.axpy_i4(
                            xv,
                            &packed[kk * stride..][..stride],
                            &scales[(kk / group) * n..][..n],
                            &mut want,
                        );
                    }
                }
            }
            ProjStorage::SparseCsr { row_ptr, col_idx, vals, .. } => {
                for (kk, &xv) in x.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let (a, b) =
                        (row_ptr[kk] as usize, row_ptr[kk + 1] as usize);
                    match vals {
                        CsrVals::F16(v) => sc.csr_axpy_f16(
                            xv,
                            &col_idx[a..b],
                            &v[a..b],
                            &mut want,
                        ),
                        CsrVals::I8 { vals, scales, group } => sc
                            .csr_axpy_i8(
                                xv,
                                &col_idx[a..b],
                                &vals[a..b],
                                &scales[(kk / group) * n..][..n],
                                &mut want,
                            ),
                    }
                }
            }
        }
        for (j, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "{} ({:?}) out[{j}]",
                s.encoding_name(),
                simd::active()
            );
        }
    }
}

/// Each quantized kernel is exactly one weight pass per call, at any
/// batch width (the single-weight-pass contract the batched decode
/// relies on).
#[test]
fn quantized_kernels_count_one_weight_pass() {
    let (k, n, group) = (24, 16, 8);
    let t = sparse_tensor(41, k, n, 0.6);
    let x1 = vec![0.5f32; k];
    let x8 = Tensor::new(vec![0.25f32; 8 * k], vec![8, k]);
    for s in [
        ProjStorage::seal_i8(&t, group),
        ProjStorage::seal_i4(&t, group),
        ProjStorage::seal_csr_i8(&t, group),
    ] {
        let mut y = vec![0.0f32; n];
        let before = weight_passes();
        matvec_storage(&x1, &s, &mut y);
        assert_eq!(weight_passes() - before, 1, "{}", s.encoding_name());
        let before = weight_passes();
        let _ = matmul_storage(&x8, &s);
        assert_eq!(
            weight_passes() - before,
            1,
            "{} width 8",
            s.encoding_name()
        );
    }
}

/// The GPTQ+seal production pipeline is worker-count invariant: the
/// quantized sealed storage (codes, scales, patterns) must be
/// bit-identical at workers=1 and workers=4.
#[test]
fn quant_pipeline_worker_invariant() {
    let src = random_model_sized(51, 3, 32, 2, 80, 64, 16);
    let pl: PruningPlan = {
        let mut rank: Vec<Vec<f64>> = {
            let mut rng = Pcg32::seeded(52);
            (0..3).map(|_| (0..7).map(|_| rng.f64() * 3.0).collect()).collect()
        };
        normalize_rank(&mut rank);
        plan(&GlobalRank { rank, alpha: 5.0 }, 0.8, Uniformity::Projection)
    };
    let run = |workers: usize| {
        let opts = ProduceOpts::new(PrunerKind::Magnitude)
            .with_workers(workers)
            .with_quant(QuantSpec::i8(32));
        produce(&src, &pl, &[], &opts).model
    };
    let (a, b) = (run(1), run(4));
    for (li, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate()
    {
        for (pi, (x, y)) in
            la.projs.iter().zip(lb.projs.iter()).enumerate()
        {
            assert!(!x.is_dense_f32(), "l{li} p{pi} must be sealed");
            assert!(
                x == y,
                "l{li} p{pi}: {} vs {}",
                x.encoding_name(),
                y.encoding_name()
            );
        }
    }
    // ~80% pruning + i8 spec lands at least some projections on csr8
    assert!(a
        .layers
        .iter()
        .flat_map(|l| l.projs.iter())
        .any(|s| s.encoding_name() == "csr8"));
}

/// Header-v3 round-trip: a pruned+quantized model exports, loads back
/// into the *same* storage (PartialEq over codes/scales/patterns), and
/// re-exports byte-identically; logits are bit-identical across the
/// trip, and the quantized seal is strictly smaller resident than the
/// f16/CSR-f16 seal of the same weights.
#[test]
fn quantized_export_load_roundtrip_byte_exact() {
    let m = pruned_quantized_model(61, 32);
    let path = std::env::temp_dir().join("mosaic_quant_rt.bin");
    deploy::export_model(&m, &path).unwrap();
    let file = std::fs::read(&path).unwrap();
    let hlen =
        u64::from_le_bytes(file[..8].try_into().unwrap()) as usize;
    let header = std::str::from_utf8(&file[8..8 + hlen]).unwrap();
    assert!(header.contains("\"version\":3"));
    assert!(header.contains("csr8"), "quant encodings in the header");
    let loaded = deploy::load_encoded(&path).unwrap();
    for (la, lb) in m.layers.iter().zip(loaded.layers.iter()) {
        for (x, y) in la.projs.iter().zip(lb.projs.iter()) {
            assert!(x == y, "{} vs {}", x.encoding_name(), y.encoding_name());
        }
    }
    assert_eq!(m.resident_bytes(), loaded.resident_bytes());
    // bit-identical logits across the export/load trip
    let toks: Vec<u16> = vec![1, 8, 3, 5];
    let (a, b) = (forward_full(&m, &toks), forward_full(&loaded, &toks));
    for (x, y) in a.data.iter().zip(b.data.iter()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    // re-export of the loaded model is the same file, byte for byte
    let path2 = std::env::temp_dir().join("mosaic_quant_rt2.bin");
    deploy::export_model(&loaded, &path2).unwrap();
    assert_eq!(file, std::fs::read(&path2).unwrap());
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&path2).ok();
    // strictly smaller than the unquantized seal of the same weights
    let mut f16_seal = pruned_quantized_model(61, 32);
    f16_seal.decompact();
    f16_seal.compact();
    assert!(
        m.resident_bytes() < f16_seal.resident_bytes(),
        "{} vs {}",
        m.resident_bytes(),
        f16_seal.resident_bytes()
    );
}

/// A pruned+quantized model serves through the registry over real TCP:
/// greedy replies are deterministic and equal to a local engine decode
/// of the same weights.
#[test]
fn quantized_model_serves_through_registry() {
    let m = pruned_quantized_model(71, 32);
    let local = m.clone();
    let mut reg = ModelRegistry::new();
    reg.register("q70", m).unwrap();
    let srv =
        Server::start_registry(reg, ServeConfig::default(), 0).unwrap();
    let mut client = Client::connect(srv.addr).unwrap();
    let prompt: Vec<u16> = vec![2, 9, 4];
    let req = GenRequest::greedy(&prompt).max_new(6).model("q70");
    let r1 = client.generate(&req).unwrap();
    let r2 = client.generate(&req).unwrap();
    assert_eq!(r1.tokens, r2.tokens, "greedy serving is deterministic");
    assert!(!r1.tokens.is_empty());
    // local greedy reference over the same sealed weights
    let mut st = DecodeState::new(&local, local.cfg.ctx);
    let mut last = *prompt.last().unwrap();
    for &t in &prompt[..prompt.len() - 1] {
        decode_step(&local, &mut st, t);
    }
    let mut want = Vec::new();
    for _ in 0..6 {
        let logits = decode_step(&local, &mut st, last);
        let next = argmax(logits) as u16;
        want.push(next);
        last = next;
    }
    assert_eq!(r1.tokens, want, "served tokens match local decode");
}
