//! Parity/determinism harness for the streaming layer-parallel
//! production pipeline: for every pruner, `prune::pipeline` must be
//! BIT-identical — weights, masks, kept-structure metadata, sealed
//! storage encodings — to the sequential reference
//! (`prune_*` + `compact()`) at any worker count. Both paths read the
//! same calibration snapshot, so any divergence is a pipeline bug, not
//! a statistics difference.

use mosaic::model::capture::capture_calibration;
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::prune::pipeline::{
    produce_with_snapshot, sequential_reference, ProduceOpts, PrunerKind,
};
use mosaic::prune::planner::PruningPlan;
use mosaic::prune::semistructured::check_nm_storage;
use mosaic::prune::{plan, CompositeOpts, Uniformity};
use mosaic::rank::{normalize_rank, GlobalRank};
use mosaic::util::rng::Pcg32;

fn test_model(seed: u64, layers: usize) -> ModelWeights {
    random_model_sized(seed, layers, 16, 2, 40, 64, 16)
}

fn calib_samples() -> Vec<Vec<u16>> {
    (0..4)
        .map(|s| {
            (0..12)
                .map(|i| ((i * 7 + s * 13) % 60 + 2) as u16)
                .collect()
        })
        .collect()
}

/// Non-uniform projection-level plan so per-projection targets differ
/// (the parity claim must hold beyond the uniform case).
fn test_plan(seed: u64, layers: usize, p: f64) -> PruningPlan {
    let mut rng = Pcg32::seeded(seed);
    let mut rank: Vec<Vec<f64>> = (0..layers)
        .map(|_| (0..7).map(|_| rng.f64() * 3.0).collect())
        .collect();
    normalize_rank(&mut rank);
    plan(&GlobalRank { rank, alpha: 5.0 }, p, Uniformity::Projection)
}

fn assert_models_identical(
    want: &ModelWeights,
    got: &ModelWeights,
    kind: &str,
    workers: usize,
) {
    let tag = format!("{kind} workers={workers}");
    assert_eq!(want.layers.len(), got.layers.len(), "{tag}: layer count");
    assert_eq!(want.embed.data, got.embed.data, "{tag}: embed");
    assert_eq!(want.lm_head.data, got.lm_head.data, "{tag}: lm_head");
    assert_eq!(want.final_norm, got.final_norm, "{tag}: final_norm");
    for (li, (a, b)) in
        want.layers.iter().zip(got.layers.iter()).enumerate()
    {
        assert_eq!(a.kept_heads, b.kept_heads, "{tag} l{li}: kept_heads");
        assert_eq!(
            a.kept_channels, b.kept_channels,
            "{tag} l{li}: kept_channels"
        );
        assert_eq!(a.attn_norm, b.attn_norm, "{tag} l{li}: attn_norm");
        assert_eq!(a.ffn_norm, b.ffn_norm, "{tag} l{li}: ffn_norm");
        for (pi, (x, y)) in a.projs.iter().zip(b.projs.iter()).enumerate()
        {
            assert!(
                x == y,
                "{tag} l{li} p{pi}: storage mismatch ({} vs {})",
                x.encoding_name(),
                y.encoding_name()
            );
        }
    }
}

#[test]
fn pipeline_bit_identical_to_sequential_all_pruners() {
    let layers = 6;
    let m = test_model(7001, layers);
    let pl = test_plan(11, layers, 0.6);
    let snap = capture_calibration(&m, &calib_samples(), true);
    let stats = &snap.stats;
    let hess = snap.hess.as_ref().expect("grams requested");
    let kinds = [
        PrunerKind::Magnitude,
        PrunerKind::Wanda,
        PrunerKind::SparseGpt,
        PrunerKind::SemiStructured { n: 2, m: 4 },
        PrunerKind::Structured,
        // the Mosaic composite rides along in both flavours
        PrunerKind::Composite(CompositeOpts {
            use_obs: true,
            ..Default::default()
        }),
        PrunerKind::Composite(CompositeOpts::default()),
    ];
    for kind in kinds {
        let want = sequential_reference(&kind, &m, &pl, stats, hess);
        for workers in [1usize, 2, 8] {
            let rep = produce_with_snapshot(
                &m,
                &pl,
                Some(stats),
                Some(hess),
                &ProduceOpts::new(kind).with_workers(workers),
            );
            assert_models_identical(
                &want,
                &rep.model,
                kind.name(),
                workers,
            );
            assert_eq!(
                rep.sealed_bytes,
                want.resident_bytes(),
                "{} workers={workers}: sealed size",
                kind.name()
            );
        }
    }
}

#[test]
fn streaming_peak_stays_below_dense_model() {
    // the memory story: sequential production clones the FULL dense
    // model; the pipeline's working set is sealed prefix + in-flight
    // dense layers, which must stay below one dense model.
    let layers = 12;
    let m = test_model(7002, layers);
    let pl = PruningPlan::uniform(layers, 0.5);
    let rep = produce_with_snapshot(
        &m,
        &pl,
        None,
        None,
        &ProduceOpts::new(PrunerKind::Magnitude).with_workers(2),
    );
    let dense = m.model_bytes();
    assert!(
        rep.peak_resident_bytes < dense,
        "peak {} must stay below dense {}",
        rep.peak_resident_bytes,
        dense
    );
    assert!(rep.sealed_bytes < dense, "sealed output must be smaller");
    // every projection sealed, not just some (is_compacted is an ANY)
    assert!(rep
        .model
        .layers
        .iter()
        .flat_map(|l| l.projs.iter())
        .all(|s| !s.is_dense_f32()));
    assert!(
        rep.peak_resident_bytes >= rep.sealed_bytes,
        "peak covers at least the sealed output"
    );
}

#[test]
fn nm_pattern_survives_pipeline_seal_including_csr() {
    // closes the gap where check_nm only ever ran on dense tensors:
    // after pipeline N:M pruning every SEALED projection must still
    // satisfy the pattern — including CSR layers (decode-then-check).
    let layers = 4;
    let m = test_model(7003, layers);
    let pl = PruningPlan::uniform(layers, 0.5); // N:M ignores targets
    let snap = capture_calibration(&m, &calib_samples(), false);
    for (n, mm) in [(2usize, 4usize), (1, 8)] {
        let rep = produce_with_snapshot(
            &m,
            &pl,
            Some(&snap.stats),
            None,
            &ProduceOpts::new(PrunerKind::SemiStructured { n, m: mm })
                .with_workers(2),
        );
        for (li, l) in rep.model.layers.iter().enumerate() {
            for (pi, s) in l.projs.iter().enumerate() {
                assert!(!s.is_dense_f32(), "l{li} p{pi} must be sealed");
                assert!(
                    check_nm_storage(s, n, mm),
                    "{n}:{mm} violated at l{li} p{pi} (enc {})",
                    s.encoding_name()
                );
            }
        }
        if (n, mm) == (1, 8) {
            // 87.5 % sparsity clears the CSR size crossover
            assert!(
                rep.model
                    .layers
                    .iter()
                    .flat_map(|l| l.projs.iter())
                    .any(|s| s.encoding_name() == "csr"),
                "1:8 pruning should seal projections to CSR"
            );
        }
    }
}
