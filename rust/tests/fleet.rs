//! Fleet serving: scale-to-zero lifecycle and weighted canary
//! routing, end-to-end through the public server surface. Three
//! properties the fleet layer must hold:
//!
//! * **lifecycle bit-identity** — greedy output is byte-identical
//!   across cold-spawn → serve → idle-unload → re-wake, for a dense
//!   and a sealed-70 artifact, at batch widths 1/2/8; the gauges
//!   (`kv_pages_in_use`, `inflight`, `queue_depth`) return to zero
//!   after an unload.
//! * **routing determinism** — the live traffic split replays the
//!   seeded [`RouterTable`] pick stream *exactly*, request for
//!   request, and `Server::route_stats` tallies agree.
//! * **failover** — a backend whose artifact is gone goes Down on
//!   first wake and the routed split renormalizes onto the survivor.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use mosaic::model::weights::testutil::random_model_sized;
use mosaic::model::ModelWeights;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::serve::lifecycle::LifecycleState;
use mosaic::serve::router::{parse_route, RouterTable};
use mosaic::serve::{
    wait_reply, HealthState, ModelRegistry, Reply, ServeConfig, Server,
    SubmitSpec,
};

const PROMPTS: &[&[u16]] = &[&[1, 9, 4], &[7, 2, 2, 5], &[3, 60, 11]];
const MAX_NEW: usize = 10;

fn model(seed: u64) -> ModelWeights {
    random_model_sized(seed, 2, 16, 2, 40, 64, 16)
}

/// Magnitude-prune every projection to 70% sparsity and compact —
/// the sealed-variant shape the fleet serves next to its dense parent.
fn sealed70(dense: &ModelWeights) -> ModelWeights {
    let mut m = dense.clone();
    for l in m.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    m.compact();
    m
}

/// Export `m` to a temp `.mosaic` artifact and return the path.
fn export(m: &ModelWeights, tag: &str) -> std::path::PathBuf {
    let path = std::env::temp_dir().join(format!("fleet_{tag}.mosaic"));
    mosaic::deploy::export_model(m, &path).expect("export");
    path
}

fn greedy_to(model: &str, prompt: &[u16]) -> SubmitSpec {
    SubmitSpec {
        model: Some(model.to_string()),
        ..SubmitSpec::greedy(prompt, MAX_NEW)
    }
}

/// Serve every prompt against `model`, returning the token streams.
fn serve_all(srv: &Server, model: &str) -> Vec<Vec<u16>> {
    PROMPTS
        .iter()
        .map(|p| {
            let rx = srv.submit_spec(greedy_to(model, p)).expect("admit");
            wait_reply(&rx, Duration::from_secs(60))
                .expect("reply")
                .tokens
        })
        .collect()
}

fn await_lifecycle(srv: &Server, name: &str, want: LifecycleState) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let got = srv.engine_lifecycle(name).expect("registered");
        if got == want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{name}: stuck in {got:?}, wanted {want:?}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Cold-spawn → serve → idle-unload → re-wake keeps greedy output
/// byte-identical to a hot server over the same weights, dense and
/// sealed-70, across batch widths; gauges return to zero after the
/// unload.
#[test]
fn lifecycle_bit_identity_across_unload_cycles() {
    let dense = model(601);
    let s70 = sealed70(&dense);
    let paths = [
        ("dense", export(&dense, "identity_dense")),
        ("s70", export(&s70, "identity_s70")),
    ];
    // the hot reference: same weights, resident from the start
    let mut hot_reg = ModelRegistry::new();
    hot_reg.register("dense", dense).unwrap();
    hot_reg.register("s70", s70).unwrap();
    let hot =
        Server::start_registry(hot_reg, ServeConfig::default(), 0).unwrap();
    let want: Vec<(&str, Vec<Vec<u16>>)> = paths
        .iter()
        .map(|(name, _)| (*name, serve_all(&hot, name)))
        .collect();
    hot.shutdown();

    for width in [1usize, 2, 8] {
        let mut reg = ModelRegistry::new();
        for (name, path) in &paths {
            reg.register_cold(name, path).unwrap();
        }
        let srv = Server::start_registry(
            reg,
            ServeConfig {
                max_batch: width,
                idle_ms: Some(150),
                ..Default::default()
            },
            0,
        )
        .unwrap();
        for (name, expect) in &want {
            assert_eq!(
                srv.engine_lifecycle(name),
                Some(LifecycleState::Cold),
                "{name} must register cold (width {width})"
            );
            // cold-spawn: first request wakes the engine
            assert_eq!(&serve_all(&srv, name), expect, "cold wake w{width}");
            assert_eq!(
                srv.engine_lifecycle(name),
                Some(LifecycleState::Hot)
            );
            // idle reaper: weights + KV pages dropped, entry re-parked
            await_lifecycle(&srv, name, LifecycleState::Cold);
            let stats = srv.model_stats(name).unwrap();
            for (gauge, v) in [
                ("kv_pages_in_use", &stats.kv_pages_in_use),
                ("kv_pages_total", &stats.kv_pages_total),
                ("queue_depth", &stats.queue_depth),
                ("inflight", &stats.inflight),
            ] {
                assert_eq!(
                    v.load(Ordering::Relaxed),
                    0,
                    "{name}/{gauge} after unload (width {width})"
                );
            }
            // re-wake: identical bytes on the second life
            assert_eq!(&serve_all(&srv, name), expect, "re-wake w{width}");
            assert_eq!(
                srv.engine_health(name),
                Some(HealthState::Healthy),
                "unload cycles must not look like failures"
            );
        }
        srv.shutdown();
    }
    for (_, path) in &paths {
        let _ = std::fs::remove_file(path);
    }
}

/// The live split replays the seeded pick stream exactly: an
/// independent [`RouterTable`] with the same defs + seed predicts the
/// serving backend of every single request, and `route_stats` tallies
/// the same counts in configured backend order.
#[test]
fn routed_traffic_replays_the_table_exactly() {
    const N: usize = 200;
    let route = "chat=a:70,b:30";
    let mut reg = ModelRegistry::new();
    reg.register("a", model(611)).unwrap();
    reg.register("b", model(612)).unwrap();
    let srv = Server::start_registry(
        reg,
        ServeConfig {
            routes: vec![parse_route(route).unwrap()],
            route_seed: 42,
            ..Default::default()
        },
        0,
    )
    .unwrap();
    assert_eq!(srv.routes(), vec!["chat".to_string()]);
    // sequential admissions consume the route's pick stream in call
    // order — the determinism rule under test
    let served: Vec<Reply> = (0..N)
        .map(|i| {
            let p = [1 + (i % 7) as u16, 9, 4];
            let rx = srv.submit_spec(greedy_to("chat", &p)).expect("admit");
            wait_reply(&rx, Duration::from_secs(60)).expect("reply")
        })
        .collect();
    let replay = RouterTable::new(vec![parse_route(route).unwrap()], 42)
        .unwrap();
    for (i, r) in served.iter().enumerate() {
        let (rname, backend) =
            replay.pick("chat", |_| false).unwrap().unwrap();
        assert_eq!(*rname, "chat");
        assert_eq!(
            r.model, backend,
            "request {i} must land on the replayed pick"
        );
        assert_eq!(r.route.as_deref(), Some("chat"));
    }
    // side-by-side stats in configured order, tallies exact
    let per: Vec<(String, u64)> = srv
        .route_stats("chat")
        .iter()
        .map(|(n, s)| (n.clone(), s.accepted.load(Ordering::Relaxed)))
        .collect();
    let count =
        |b: &str| served.iter().filter(|r| r.model == b).count() as u64;
    assert_eq!(
        per,
        vec![("a".to_string(), count("a")), ("b".to_string(), count("b"))]
    );
    assert_eq!(count("a") + count("b"), N as u64);
    // a direct (non-routed) request bypasses the table: no route tag,
    // no pick-stream draw
    let rx = srv.submit_spec(greedy_to("a", &[1, 9, 4])).unwrap();
    let direct = wait_reply(&rx, Duration::from_secs(60)).unwrap();
    assert_eq!(direct.route, None);
    assert_eq!(direct.model, "a");
    srv.shutdown();
}

/// A cold backend whose artifact vanished goes Down on first wake
/// (terminal, typed `EngineDown` error — not a hang), and the weighted
/// split renormalizes onto the surviving peer.
#[test]
fn missing_artifact_goes_down_and_routes_fail_over() {
    let ghost_path = export(&model(621), "ghost");
    let mut reg = ModelRegistry::new();
    reg.register("live", model(622)).unwrap();
    reg.register_cold("ghost", &ghost_path).unwrap();
    let srv = Server::start_registry(
        reg,
        ServeConfig {
            routes: vec![parse_route("r=ghost:50,live:50").unwrap()],
            route_seed: 9,
            default_model: Some("live".into()),
            ..Default::default()
        },
        0,
    )
    .unwrap();
    // the artifact disappears while the engine is parked cold
    std::fs::remove_file(&ghost_path).unwrap();
    let rx = srv.submit_spec(greedy_to("ghost", &[1, 2, 3])).unwrap();
    let err = wait_reply(&rx, Duration::from_secs(60))
        .expect_err("wake must fail without the artifact")
        .to_string();
    assert!(err.contains("failed to wake"), "{err}");
    let deadline = Instant::now() + Duration::from_secs(20);
    while srv.engine_health("ghost") != Some(HealthState::Down) {
        assert!(Instant::now() < deadline, "ghost never went Down");
        std::thread::sleep(Duration::from_millis(5));
    }
    // every routed request now lands on the survivor
    for _ in 0..40 {
        let rx = srv.submit_spec(greedy_to("r", &[1, 9, 4])).unwrap();
        let r = wait_reply(&rx, Duration::from_secs(60)).expect("failover");
        assert_eq!(r.model, "live");
        assert_eq!(r.route.as_deref(), Some("r"));
    }
    srv.shutdown();
}
