//! Integration: the native rust engine must match the AOT jax/Pallas
//! graph (via PJRT) bit-closely on dense AND masked weights — the
//! numerical contract that lets the deployer swap engines.
//!
//! Requires artifacts (run `make artifacts` first). Skips gracefully if
//! they are absent so `cargo test` works in a fresh checkout.

use mosaic::eval::{perplexity_native, perplexity_pjrt};
use mosaic::model::engine::forward_full;
use mosaic::model::ModelWeights;
use mosaic::prune::{plan, prune_unstructured, Metric, Uniformity};
use mosaic::rank::GlobalRank;
use mosaic::runtime::ModelRuntime;
use mosaic::Artifacts;

fn artifacts() -> Option<Artifacts> {
    Artifacts::discover().ok()
}

#[test]
fn native_matches_pjrt_dense() {
    let Some(a) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dir = a.model_dir("tl1_7");
    let weights = ModelWeights::load(&dir).unwrap();
    let mut rt = ModelRuntime::load(&dir).unwrap();
    let (bsz, s) = rt.fwd_tokens_shape;
    // deterministic tokens
    let toks: Vec<i32> =
        (0..bsz * s).map(|i| 3 + (i as i32 * 17) % 500).collect();
    let pjrt_logits = rt.forward(&toks).unwrap();
    let vocab = weights.cfg.vocab;
    for bi in 0..bsz {
        let row: Vec<u16> =
            toks[bi * s..(bi + 1) * s].iter().map(|&t| t as u16).collect();
        let native = forward_full(&weights, &row);
        let mut max_err = 0f32;
        for i in 0..s * vocab {
            let p = pjrt_logits[bi * s * vocab + i];
            let n = native.data[i];
            max_err = max_err.max((p - n).abs());
        }
        assert!(
            max_err < 2e-2,
            "batch {bi}: native vs pjrt max err {max_err}"
        );
    }
}

#[test]
fn native_matches_pjrt_masked() {
    let Some(a) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dir = a.model_dir("tl1_7");
    let mut weights = ModelWeights::load(&dir).unwrap();
    let rank = GlobalRank {
        rank: vec![vec![1.0; 7]; weights.cfg.n_layers],
        alpha: 5.0,
    };
    let pl = plan(&rank, 0.5, Uniformity::Global);
    prune_unstructured(&mut weights, &pl, None, Metric::Magnitude);

    let mut rt = ModelRuntime::load(&dir).unwrap();
    rt.set_weights(&weights).unwrap();
    let (bsz, s) = rt.fwd_tokens_shape;
    let toks: Vec<i32> =
        (0..bsz * s).map(|i| 3 + (i as i32 * 29) % 500).collect();
    let pjrt_logits = rt.forward(&toks).unwrap();
    let vocab = weights.cfg.vocab;
    let row: Vec<u16> = toks[..s].iter().map(|&t| t as u16).collect();
    let native = forward_full(&weights, &row);
    let mut max_err = 0f32;
    for i in 0..s * vocab {
        max_err = max_err.max((pjrt_logits[i] - native.data[i]).abs());
    }
    assert!(max_err < 2e-2, "masked parity err {max_err}");
}

#[test]
fn perplexity_paths_agree() {
    let Some(a) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dir = a.model_dir("tl1_7");
    let weights = ModelWeights::load(&dir).unwrap();
    let store =
        mosaic::data::DataStore::load(&a.data_dir()).unwrap();
    let stream = store.split("wikitext2s").unwrap();
    let mut rt = ModelRuntime::load(&dir).unwrap();
    let (b, s) = rt.fwd_tokens_shape;
    let n_batches = 3;
    let ppl_pjrt = perplexity_pjrt(&mut rt, &stream, n_batches).unwrap();
    let ppl_native =
        perplexity_native(&weights, &stream, s, n_batches * b);
    let rel = (ppl_pjrt - ppl_native).abs() / ppl_native;
    assert!(
        rel < 0.02,
        "PPL disagree: pjrt {ppl_pjrt} native {ppl_native}"
    );
}

#[test]
fn weight_metric_kernel_matches_rust_pod() {
    let Some(a) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dir = a.model_dir("tl1_7");
    let weights = ModelWeights::load(&dir).unwrap();
    let mut rt = ModelRuntime::load(&dir).unwrap();
    let w = weights.layers[0].projs[0].dense().clone();
    let act: Vec<f32> = (0..w.shape[0]).map(|i| 1.0 + i as f32).collect();
    let (count, _sum) = rt.weight_metric(&w, &act).unwrap();
    let ratio = mosaic::rank::pod_outlier_ratio(&w, &act, 5.0);
    let expect = ratio * w.numel() as f64;
    assert!(
        (count as f64 - expect).abs() <= 1.0,
        "pallas kernel {count} vs rust {expect}"
    );
}
