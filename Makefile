# Build / verify entry points. Tier-1 is `make build test`; `make check`
# adds formatting + lint gates (skipped gracefully when the component is
# not installed in the image).

CARGO ?= cargo

.PHONY: build test fmt check bench bench-serve bench-produce \
	bench-spec bench-kv bench-chaos bench-fleet bench-quant \
	bench-shards serve-smoke spec-smoke fleet-smoke quant-smoke \
	shard-smoke chaos

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt

check:
	@if $(CARGO) fmt --version >/dev/null 2>&1; then \
		$(CARGO) fmt --check; \
	else \
		echo "make check: rustfmt unavailable — skipping fmt gate"; \
	fi
	@if $(CARGO) clippy --version >/dev/null 2>&1; then \
		$(CARGO) clippy --all-targets --features chaos -- -D warnings; \
		$(CARGO) clippy --all-targets \
			--features chaos,simd-force-scalar -- -D warnings; \
	else \
		echo "make check: clippy unavailable — skipping lint gate"; \
	fi
	$(CARGO) test -q

bench:
	$(CARGO) bench

# Serving perf trajectory: runs the continuous-batching bench and emits
# machine-readable BENCH_serve.json (tok/s, occupancy, resident bytes;
# includes registry rows: dense vs sealed variant from one process).
bench-serve:
	$(CARGO) bench --bench serve_throughput

# End-to-end serve smoke (artifact-free): registry server on
# random-weights models, greedy + sampled + streaming + stop-token
# requests driven through the typed client over real TCP. Wired into
# pytest via python/tests/test_serve_smoke.py.
serve-smoke:
	$(CARGO) run --release --example serve_client

# Speculative-serving perf trajectory: pruned-draft / dense-verify
# pairs swept over draft depth K ∈ {0 (off), 2, 4, 8} at widths 1/4;
# every row parity-checked against target-only output before it is
# recorded. Emits machine-readable BENCH_spec.json (tok/s, acceptance
# rate, p95).
bench-spec:
	$(CARGO) bench --bench spec_speed

# Paged-KV capacity trajectory: slab vs observed-residency vs
# prefix-reuse admission at one fixed page budget, parity-checked
# (decoded tokens identical across modes; shared cached head costs
# zero prefill weight passes). Emits machine-readable BENCH_kv.json.
# Wired into pytest via python/tests/test_kv_smoke.py.
bench-kv:
	$(CARGO) bench --bench kv_paging

# Speculative-serving smoke (artifact-free): dense + sealed-70% draft
# + pair registry over real TCP; asserts greedy spec replies are
# byte-identical to dense-only replies and sampled streams are
# acceptance-invariant. Wired into pytest via
# python/tests/test_spec_smoke.py.
spec-smoke:
	$(CARGO) run --release --example spec_smoke

# Seeded fault-schedule property suite: panics/stalls/queue drops
# injected at engine checkpoints must leave every request with exactly
# one terminal event, gauges at zero, and bit-identical post-restart
# output. Fixed seeds for CI determinism plus one exploratory run that
# prints its seed (reproduce failures with CHAOS_SEED=<seed>). Wired
# into pytest via python/tests/test_chaos_smoke.py.
chaos:
	$(CARGO) test --test chaos --features chaos -- --nocapture
	$(CARGO) test --test shard_parity --features chaos -- --nocapture
	@echo "CHAOS OK"

# Robustness perf: supervision overhead at 0% faults (full supervised
# server vs a bare engine thread) and tok/s recovery time after an
# injected engine crash. Merges section "chaos*" rows into
# BENCH_serve.json next to the serve_throughput rows.
bench-chaos:
	$(CARGO) bench --bench chaos_recovery --features chaos

# Fleet capacity trajectory: open-loop arrival-scheduled load over
# real TCP against a routed fleet (dense parent + cold sealed-70%
# canary) at sweeping rates; records p50/p95/p99 from the scheduled
# arrival, the saturation knee, cold-wake latency, and parity across
# an idle-unload/re-wake cycle. Merges section "fleet*" rows into
# BENCH_serve.json next to the serve_throughput and chaos rows.
bench-fleet:
	$(CARGO) bench --bench fleet_load

# Fleet-serving smoke (artifact-backed): sealed 70%-pruned variant
# registered cold from a .mosaic file behind a weighted canary route;
# asserts cold spawn on first request, route echo on the wire, and
# byte-identical output across one idle-unload/re-wake cycle. Wired
# into pytest via python/tests/test_fleet_smoke.py.
fleet-smoke:
	$(CARGO) run --release --example fleet_smoke

# Sharded-execution smoke (artifact-free): one weight set served
# unsharded, as a 2-replica group, and as a 2-stage layer-range
# pipeline over real TCP; asserts byte-identical greedy output in both
# shard modes (serial + concurrent burst), Arc-deduped resident
# accounting, and the {"stats": true} introspection line. Wired into
# pytest via python/tests/test_shard_smoke.py.
shard-smoke:
	$(CARGO) run --release --example shard_smoke

# Shard scaling trajectory: closed-loop tok/s at replica widths
# N ∈ {1, 2, 4} with per-engine batch capped (the ceiling replicas
# lift) plus 2/3-stage pipeline handoff overhead, every configuration
# parity-checked against the unsharded engine before its row is
# recorded. Merges section "shard*" rows into BENCH_serve.json next to
# the serve_throughput, chaos, and fleet rows.
bench-shards:
	$(CARGO) bench --bench shard_scale

# Quantized-storage perf trajectory: sparsity × precision × width sweep
# over the runtime storage kernels (f32/f16/csr/i8/i4/csr8), every row
# bit-parity-checked against the decoded-dense oracle before it is
# recorded, plus the e2e acceptance row (csr8 seal strictly smaller
# resident than the f16/CSR seal, byte-exact export round trip, TCP
# serve parity). Emits machine-readable BENCH_quant.json.
bench-quant:
	$(CARGO) bench --bench quant_speed

# Quantized-serving smoke (artifact-free): pruned+quantized (i8:32,
# csr8-sealed) model exported to a header-v3 .mosaic, loaded back and
# served over real TCP next to its dense parent; asserts resident-size
# ordering, byte-exact round trip, and greedy parity with a local
# engine decode. Wired into pytest via
# python/tests/test_quant_smoke.py.
quant-smoke:
	$(CARGO) run --release --example quant_smoke

# Model-production perf trajectory: sequential whole-model pruning vs
# the streaming layer-parallel pipeline at 1/2/4/8 workers; emits
# machine-readable BENCH_produce.json (per-stage ms, peak resident
# bytes, speedup).
bench-produce:
	$(CARGO) bench --bench produce_speed
