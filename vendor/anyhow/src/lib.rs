//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! This workspace builds fully offline, so instead of a registry
//! dependency we ship the small subset of anyhow's API the code uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros
//! and the [`Context`] extension trait for `Result` and `Option`.
//! Semantics match upstream for that subset (context wraps the message,
//! the original error is kept as `source`).

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error that remembers the error it wrapped.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    pub fn new(msg: String) -> Error {
        Error { msg, source: None }
    }

    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error::new(m.to_string())
    }

    /// Wrap with an outer context message (upstream `Error::context`).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
            source: self.source,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().map(|s| s as &dyn StdError);
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::new(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::new(::std::format!($($t)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn context_on_result_and_option() {
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("plain {}", "msg");
        assert_eq!(e.to_string(), "plain msg");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = io_err().context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer: boom"));
        assert!(dbg.contains("Caused by"));
    }
}
