//! Offline stub of the `xla` (PJRT / xla_extension) bindings.
//!
//! This image has no xla_extension shared library and no registry
//! access, so the workspace vendors this API-compatible stub: every
//! entry point returns an "unavailable" error at *runtime*, while the
//! `runtime::ModelRuntime` code keeps compiling unchanged. The PJRT
//! paths already degrade gracefully — artifact-dependent tests and
//! benches skip when `ModelRuntime::load` fails — and the native engine
//! (the L3 deployment substrate) covers every runtime scenario.
//!
//! To run the real PJRT paths, point the `xla` dependency in the root
//! Cargo.toml at the actual bindings instead of this stub.

use std::fmt;

/// Error carrying the unavailability message (or any stub failure).
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "xla/PJRT unavailable: built with the vendored stub \
             (see vendor/xla-stub)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

pub struct PjRtClient {
    _priv: (),
}

pub struct PjRtDevice {
    _priv: (),
}

pub struct PjRtBuffer {
    _priv: (),
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

pub struct HloModuleProto {
    _priv: (),
}

pub struct XlaComputation {
    _priv: (),
}

pub struct Literal {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable())
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
