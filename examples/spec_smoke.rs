//! Speculative-serving smoke (`make spec-smoke`): registry of a dense
//! random checkpoint, its sealed 70 %-pruned variant, and a
//! speculative pair coupling them — driven over real TCP through the
//! typed client, asserting the contract the feature ships on:
//!
//!   * **greedy spec reply == dense-only reply, byte for byte**, both
//!     routed by pair name and via the `"spec"` request field, at
//!     several per-request draft depths;
//!   * seeded sampling through the pair reproduces the dense-only
//!     sampled stream exactly (same per-request PCG32 draws);
//!   * acceptance counters arrive on the wire and are coherent
//!     (accepted ≤ drafted);
//!   * streaming through a pair frames exactly like a plain request.
//!
//!     cargo run --release --example spec_smoke

use mosaic::model::weights::testutil::random_model_sized;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::{ModelRegistry, SamplingParams, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let dense = random_model_sized(23, 3, 64, 4, 176, 96, 64);
    let mut draft = dense.clone();
    for l in draft.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    draft.compact();
    println!(
        "dense {} KB, sealed draft {} KB resident",
        dense.resident_bytes() / 1024,
        draft.resident_bytes() / 1024
    );

    let mut reg = ModelRegistry::new();
    reg.register("dense", dense)?;
    reg.register("mosaic70", draft)?;
    reg.register_spec("spec70", "dense", "mosaic70", 4)?;
    let srv = Server::start_registry(
        reg,
        ServeConfig { max_batch: 4, ..Default::default() },
        0,
    )?;
    println!(
        "registry server on {} (dense, mosaic70, spec70 pair)",
        srv.addr
    );
    let mut client = Client::connect(srv.addr)?;

    // ---- 1. greedy bit-identity across prompts and draft depths
    let mut accepted_total = 0u64;
    let mut drafted_total = 0u64;
    for p0 in [1u16, 11, 23, 40] {
        let prompt = [p0, 9, 4, 7];
        let base = client.generate(
            &GenRequest::greedy(&prompt).max_new(12).model("dense"),
        )?;
        assert!(base.spec.is_none());
        // routed by pair name (registered depth 4)
        let by_name = client.generate(
            &GenRequest::greedy(&prompt).max_new(12).model("spec70"),
        )?;
        assert_eq!(
            by_name.tokens, base.tokens,
            "greedy spec reply must equal the dense reply byte-for-byte"
        );
        let u = by_name.spec.expect("pair replies carry counters");
        assert!(u.accepted <= u.drafted, "{u:?}");
        accepted_total += u.accepted;
        drafted_total += u.drafted;
        // routed via the "spec" field with per-request depths
        for k in [1usize, 2, 8] {
            let r = client.generate(
                &GenRequest::greedy(&prompt)
                    .max_new(12)
                    .model("dense")
                    .speculative(Some("mosaic70"), Some(k)),
            )?;
            assert_eq!(r.tokens, base.tokens, "k={k} must not change output");
            assert_eq!(r.model.as_deref(), Some("spec70"));
        }
        println!(
            "prompt {prompt:?}: {:?} (accepted {}/{} drafted)",
            base.tokens, u.accepted, u.drafted
        );
    }
    println!(
        "greedy acceptance over all prompts: {accepted_total}/{drafted_total}"
    );

    // ---- 2. seeded sampling: the pair must reproduce the dense-only
    // sampled stream draw for draw
    let prompt = [1u16, 9, 4, 7];
    let sp = SamplingParams {
        temperature: 0.9,
        top_k: 16,
        top_p: 0.95,
        seed: 42,
    };
    let plain = client.generate(
        &GenRequest::greedy(&prompt).max_new(12).model("dense").sampled(sp),
    )?;
    let spec = client.generate(
        &GenRequest::greedy(&prompt)
            .max_new(12)
            .model("spec70")
            .sampled(sp),
    )?;
    println!("sampled seed=42 -> {:?}", plain.tokens);
    assert_eq!(
        spec.tokens, plain.tokens,
        "acceptance pattern must not shift the sampled stream"
    );

    // ---- 3. streaming through the pair: framing identical to plain
    let mut streamed = Vec::new();
    let r = client.generate_with(
        &GenRequest::greedy(&prompt).max_new(8).model("spec70").streaming(),
        |i, t| streamed.push((i, t)),
    )?;
    assert_eq!(streamed.len(), r.tokens.len());
    println!("streamed {} events through the pair", streamed.len());

    println!("SPEC-SMOKE OK");
    srv.shutdown();
    Ok(())
}
