//! Quickstart: load a foundation model from artifacts, run the Mosaic
//! RC→PC pipeline at one pruning level, and evaluate the result.
//!
//!     cargo run --release --example quickstart
//!
//! (run `make artifacts` first — it trains the tiny model zoo and AOT-
//! lowers the jax/Pallas graphs this example executes through PJRT.)

use mosaic::coordinator::Mosaic;
use mosaic::eval::{measure_native, mean_accuracy, perplexity_native};
use mosaic::prune::{Category, Uniformity};

fn main() -> anyhow::Result<()> {
    // 1. Load a foundation model (LLaMa-7B analogue) + datasets.
    let mut mo = Mosaic::load("tl1_7")?;
    println!(
        "loaded {} ({}): {} layers, {} params",
        mo.name, mo.dense.cfg.proxy_for, mo.dense.cfg.n_layers,
        mo.dense.cfg.n_params
    );

    // 2. Baseline quality.
    let wt = mo.store.split("wikitext2s")?;
    let seq = mo.dense.cfg.ctx.min(64);
    let dense_ppl = perplexity_native(&mo.dense, &wt, seq, 16);
    let dense_acc = mean_accuracy(&mo.dense, &mo.store)?;
    println!("dense:  PPL {dense_ppl:.2}  accuracy {dense_acc:.1}%");

    // 3. Composite projection pruning at 60 % (the Mosaic headline).
    //    Ranking profiles the model through the AOT profile graph and
    //    counts POD outliers with the Pallas weight-metric kernel.
    let (pruned, plan) =
        mo.prune(0.6, Uniformity::Projection, Category::Composite, 32)?;
    println!(
        "pruned: mean target {:.2}, bytes {} -> {}",
        plan.mean_target(),
        mo.dense.model_bytes(),
        pruned.model_bytes()
    );

    // 4. Quality + runtime of the pruned SLM on the native engine.
    let ppl = perplexity_native(&pruned, &wt, seq, 16);
    let acc = mean_accuracy(&pruned, &mo.store)?;
    let d = measure_native(&mo.dense, 32, 8, 3);
    let p = measure_native(&pruned, 32, 8, 3);
    println!("pruned: PPL {ppl:.2}  accuracy {acc:.1}%");
    println!(
        "latency: dense {:.4}s -> pruned {:.4}s ({:.0}% faster)",
        d.latency_s,
        p.latency_s,
        (1.0 - p.latency_s / d.latency_s) * 100.0
    );
    Ok(())
}
