//! Sharded-execution smoke (`make shard-smoke`): one set of weights
//! served three ways over real TCP — unsharded, as a 2-replica group,
//! and as a 2-stage layer-range pipeline — through the typed client.
//! Asserts the contract the sharded serving plane ships on:
//!
//!   * both shard modes serve greedy output byte-identical to the
//!     unsharded engine, including under a concurrent burst that
//!     spreads across replica workers;
//!   * the registry reports the shard width per entry, and resident
//!     accounting counts the Arc-shared weights once, not per entry;
//!   * the `{"stats": true}` introspection line reports every shard
//!     group's health/lifecycle/kv gauges without disturbing the v0
//!     request protocol on the same connection.
//!
//!     cargo run --release --example shard_smoke

use std::io::{BufRead, BufReader, Write};

use mosaic::model::weights::testutil::random_model_sized;
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::{ModelRegistry, ServeConfig, Server, ShardPlan};
use mosaic::util::json::Json;

fn main() -> anyhow::Result<()> {
    // four layers so the 2-stage pipeline splits real work
    let model = random_model_sized(29, 4, 64, 4, 176, 96, 64);
    let mut reg = ModelRegistry::new();
    reg.register("solo", model.clone())?;
    reg.register_sharded("rep", model.clone(), ShardPlan::Replica(2))?;
    reg.register_sharded("pipe", model, ShardPlan::Pipeline(2))?;
    let srv = Server::start_registry(
        reg,
        ServeConfig {
            max_batch: 2,
            default_model: Some("solo".into()),
            ..Default::default()
        },
        0,
    )?;
    println!(
        "shard server on {} (solo x1, rep = 2 replicas, pipe = 2 stages)",
        srv.addr
    );
    for info in srv.models() {
        println!("  {:<6} shards={}", info.name, info.shards);
    }
    let solo_bytes: usize = srv
        .models()
        .iter()
        .find(|m| m.name == "solo")
        .map(|m| m.resident_bytes)
        .unwrap_or(0);
    anyhow::ensure!(
        srv.resident_bytes_total() == solo_bytes,
        "three entries share one Arc'd weight set: total resident \
         bytes must equal one copy ({} != {})",
        srv.resident_bytes_total(),
        solo_bytes
    );
    println!(
        "resident accounting: 3 entries, 1 weight set, {} KB total",
        srv.resident_bytes_total() / 1024
    );

    // ---- 1. serial parity: each mode replays the unsharded bytes
    let mut client = Client::connect(srv.addr)?;
    let prompt = [1u16, 9, 4, 7];
    let want = client
        .generate(&GenRequest::greedy(&prompt).max_new(12).model("solo"))?
        .tokens;
    for name in ["rep", "pipe"] {
        let got = client
            .generate(
                &GenRequest::greedy(&prompt).max_new(12).model(name),
            )?
            .tokens;
        anyhow::ensure!(
            got == want,
            "{name} diverged from unsharded output"
        );
        println!("{name}: byte-identical to solo ({:?})", got);
    }

    // ---- 2. concurrent burst across the replica group: every reply
    // must match the unsharded reference for its prompt
    let prompts: Vec<Vec<u16>> =
        (0..8).map(|i| vec![1 + (i % 7) as u16, 5, 9]).collect();
    let want_burst: Vec<Vec<u16>> = prompts
        .iter()
        .map(|p| {
            client
                .generate(&GenRequest::greedy(p).max_new(8).model("solo"))
                .map(|r| r.tokens)
        })
        .collect::<Result<_, _>>()?;
    let addr = srv.addr;
    let handles: Vec<_> = prompts
        .iter()
        .cloned()
        .map(|p| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr)?;
                Ok::<_, anyhow::Error>(
                    c.generate(
                        &GenRequest::greedy(&p).max_new(8).model("rep"),
                    )?
                    .tokens,
                )
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let got = h.join().expect("burst worker")?;
        anyhow::ensure!(
            got == want_burst[i],
            "burst request {i} diverged on the replica group"
        );
    }
    println!("8-request concurrent burst on rep: all byte-identical");

    // ---- 3. stats introspection on a raw connection, then a v0
    // request on the SAME connection to prove the wire stayed v0
    let mut raw = std::net::TcpStream::connect(srv.addr)?;
    raw.write_all(b"{\"stats\": true}\n")?;
    let mut lines = BufReader::new(raw.try_clone()?).lines();
    let stats_line = lines.next().expect("stats line")?;
    let j = Json::parse(&stats_line)?;
    anyhow::ensure!(
        j.get("event").and_then(|v| v.as_str()) == Some("stats"),
        "stats line must carry event=stats"
    );
    let entries = j
        .get("entries")
        .and_then(|e| e.as_arr())
        .map(<[Json]>::to_vec)
        .unwrap_or_default();
    anyhow::ensure!(entries.len() == 3, "stats must list all 3 entries");
    for e in &entries {
        println!(
            "  stats: {} shards={} mode={} lifecycle={}",
            e.get("name").and_then(|v| v.as_str()).unwrap_or("?"),
            e.get("shards").and_then(|v| v.as_usize()).unwrap_or(0),
            e.get("mode").and_then(|v| v.as_str()).unwrap_or("?"),
            e.get("lifecycle").and_then(|v| v.as_str()).unwrap_or("?"),
        );
    }
    raw.write_all(b"{\"prompt\": [1, 9, 4, 7], \"max_new\": 4}\n")?;
    let v0 = lines.next().expect("v0 reply")?;
    anyhow::ensure!(
        v0.contains("\"tokens\"") && !v0.contains("\"event\""),
        "v0 reply bytes must stay frozen after a stats query"
    );
    println!("v0 protocol unchanged after stats query");

    println!("SHARD-SMOKE OK");
    srv.shutdown();
    Ok(())
}
