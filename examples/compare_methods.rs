//! Baseline shoot-out (Table XII analogue): LLaMa-7B proxy pruned by
//! 70 %, zero-shot accuracy of Magnitude / Wanda / SparseGPT / OWL /
//! Mosaic on all seven tasks.
//!
//!     cargo run --release --example compare_methods

use mosaic::coordinator::Mosaic;
use mosaic::eval::{mean_accuracy, per_task_accuracy};
use mosaic::prune::{
    self, plan, Category, Metric, Uniformity,
};
use mosaic::rank::GlobalRank;

fn main() -> anyhow::Result<()> {
    let mut mo = Mosaic::load("tl1_7")?;
    let p = 0.7;
    let samples = 32;
    let stats = mo.activation_stats(samples)?;
    let uniform = GlobalRank {
        rank: vec![vec![1.0; 7]; mo.dense.cfg.n_layers],
        alpha: 5.0,
    };

    let mut rows: Vec<(String, mosaic::model::ModelWeights)> = Vec::new();

    // Magnitude (global uniform, |w| metric)
    let mut m = mo.dense.clone();
    prune::prune_unstructured(
        &mut m, &plan(&uniform, p, Uniformity::Global), None,
        Metric::Magnitude);
    rows.push(("Magnitude".into(), m));

    // Wanda (global uniform, activation-weighted)
    let mut m = mo.dense.clone();
    prune::prune_unstructured(
        &mut m, &plan(&uniform, p, Uniformity::Global), Some(&stats),
        Metric::Wanda);
    rows.push(("Wanda".into(), m));

    // SparseGPT (global uniform, OBS update)
    let hess = mo.hessians(samples)?.clone_shallow();
    let mut m = mo.dense.clone();
    prune::sparsegpt::prune_sparsegpt(
        &mut m, &plan(&uniform, p, Uniformity::Global), &hess);
    rows.push(("SparseGPT".into(), m));

    // OWL (layer-wise LOD, SparseGPT pruner)
    let (m, _) = mo.prune(p, Uniformity::Layer,
                          Category::Unstructured, samples)?;
    rows.push(("OWL".into(), m));

    // Mosaic (projection POD, SparseGPT pruner)
    let (m, _) = mo.prune(p, Uniformity::Projection,
                          Category::Unstructured, samples)?;
    rows.push(("Mosaic".into(), m));

    // header
    let tasks = per_task_accuracy(&mo.dense, &mo.store)?;
    print!("{:<10}", "method");
    for (t, _) in &tasks {
        print!(" {:>7}", t);
    }
    println!(" {:>7}", "mean");
    print!("{:<10}", "dense");
    for (_, a) in &tasks {
        print!(" {:>7.1}", a);
    }
    println!(" {:>7.1}", mean_accuracy(&mo.dense, &mo.store)?);
    for (name, m) in &rows {
        let per = per_task_accuracy(m, &mo.store)?;
        print!("{name:<10}");
        for (_, a) in &per {
            print!(" {:>7.1}", a);
        }
        println!(" {:>7.1}", mean_accuracy(m, &mo.store)?);
    }
    Ok(())
}
