// Diagnostic: layered composition — layer signal + bounded within-layer
// projection refinement.
use mosaic::coordinator::Mosaic;
use mosaic::eval::perplexity_native;
use mosaic::prune::unstructured::{prune_unstructured, Metric};
use mosaic::prune::planner::PruningPlan;
use mosaic::prune::Uniformity;

fn shift(targets: &mut Vec<Vec<f64>>, p: f64) {
    for _ in 0..32 {
        let n: usize = targets.iter().map(|t| t.len()).sum();
        let mean: f64 = targets.iter().flatten().sum::<f64>() / n as f64;
        let d = p - mean;
        if d.abs() < 1e-9 { break; }
        for t in targets.iter_mut() { for x in t.iter_mut() { *x = (*x + d).clamp(0.0, 0.95); } }
    }
}

fn main() -> anyhow::Result<()> {
    for model in ["tl1_7", "tl31", "tl2_13"] {
        let mut mo = Mosaic::load(model)?;
        let stats = mo.activation_stats(32)?;
        let prank = mo.global_rank(Uniformity::Projection, 32)?;
        let lrank = mo.global_rank(Uniformity::Layer, 32)?;
        let wt = mo.store.split("wikitext2s")?;
        let seq = mo.dense.cfg.ctx.min(64);
        let lm = lrank.layer_means();
        for p in [0.8] {
            for (name, gl, gp) in [("global", 0.0, 0.0), ("layer", -0.08, 0.0), ("proj", -0.08, -0.05), ("proj03", -0.08, -0.03), ("proj08", -0.08, -0.08)] {
                let mut targets: Vec<Vec<f64>> = prank.rank.iter().enumerate().map(|(l, row)| {
                    // within-layer projection deviation
                    let rm: f64 = row.iter().sum::<f64>() / row.len() as f64;
                    row.iter().map(|&x| {
                        let zl = (1.0 - lm[l]).clamp(-1.0, 1.0);
                        let zp = if rm > 0.0 { (1.0 - x / rm).clamp(-1.0, 1.0) } else { 0.0 };
                        (p + gl * zl + gp * zp).clamp(0.0, 0.95)
                    }).collect()
                }).collect();
                shift(&mut targets, p);
                let plan = PruningPlan { targets, p, uniformity: Uniformity::Projection };
                let mut m = mo.dense.clone();
                prune_unstructured(&mut m, &plan, Some(&stats), Metric::Wanda);
                let ppl = perplexity_native(&m, &wt, seq, 12);
                println!("{model} p={p} {name:8} ppl={ppl:.1}");
            }
            println!();
        }
    }
    Ok(())
}
