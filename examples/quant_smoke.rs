//! Quantized-serving smoke (`make quant-smoke`): a pruned+quantized
//! random checkpoint — 80 % magnitude-pruned, GPTQ-quantized to i8
//! group 32, sealed through the cost table into csr8/i8 storage —
//! exported to a header-v3 `.mosaic` file, loaded back, registered
//! next to its dense parent and driven over real TCP through the
//! typed client. Asserts the contract the quantized backends ship on:
//!
//!   * at least one projection lands in the csr8 window and the sealed
//!     model is strictly smaller resident than the f16/CSR seal of the
//!     same pruned weights;
//!   * the export/load round trip preserves every projection (equal
//!     resident bytes, byte-identical re-export);
//!   * greedy replies from the served quantized model are
//!     deterministic and equal to a local engine decode of the same
//!     sealed weights, token for token.
//!
//!     cargo run --release --example quant_smoke
//!
//! Wired into pytest via python/tests/test_quant_smoke.py.

use mosaic::deploy::{self, QuantSpec};
use mosaic::model::engine::{argmax, decode_step, DecodeState};
use mosaic::model::weights::testutil::random_model_sized;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::quant::{quantize_model, QuantConfig};
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::{ModelRegistry, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let dense = random_model_sized(23, 3, 64, 4, 176, 96, 64);
    let mut pruned = dense.clone();
    for l in pruned.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.8);
        }
    }
    let mut q = pruned.clone();
    quantize_model(&mut q, None, QuantConfig { bits: 8, group: 32 });
    q.compact_q(Some(QuantSpec::i8(32)));
    let csr8 = q
        .layers
        .iter()
        .flat_map(|l| l.projs.iter())
        .filter(|s| s.encoding_name() == "csr8")
        .count();
    assert!(csr8 > 0, "no projection landed in the csr8 window");

    // the size claim: quantized seal strictly under the f16/CSR seal
    // of the same pruned weights
    let mut f16_seal = pruned;
    f16_seal.compact();
    assert!(
        q.resident_bytes() < f16_seal.resident_bytes(),
        "csr8/i8 seal must be strictly smaller: {} vs {}",
        q.resident_bytes(),
        f16_seal.resident_bytes()
    );
    println!(
        "dense {} KB, f16/csr seal {} KB, i8:32 seal {} KB \
         ({csr8} csr8 projections)",
        dense.resident_bytes() / 1024,
        f16_seal.resident_bytes() / 1024,
        q.resident_bytes() / 1024
    );

    // header-v3 export round trip, then serve the LOADED model
    let path = std::env::temp_dir().join("mosaic_quant_smoke.mosaic");
    let path2 = std::env::temp_dir().join("mosaic_quant_smoke2.mosaic");
    let shipped = deploy::export_model(&q, &path)?;
    let loaded = deploy::load_encoded(&path)?;
    assert_eq!(q.resident_bytes(), loaded.resident_bytes());
    deploy::export_model(&loaded, &path2)?;
    assert_eq!(
        std::fs::read(&path)?,
        std::fs::read(&path2)?,
        "re-export must reproduce the file byte for byte"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&path2).ok();
    println!("export round trip byte-exact ({shipped} B shipped)");

    let local = loaded.clone();
    let mut reg = ModelRegistry::new();
    reg.register("dense", dense)?;
    reg.register("q80i8", loaded)?;
    let srv = Server::start_registry(
        reg,
        ServeConfig { max_batch: 4, ..Default::default() },
        0,
    )?;
    println!("registry server on {} (dense, q80i8)", srv.addr);
    let mut client = Client::connect(srv.addr)?;

    for p0 in [2u16, 11, 40] {
        let prompt = [p0, 9, 4];
        let req = GenRequest::greedy(&prompt).max_new(8).model("q80i8");
        let r1 = client.generate(&req)?;
        let r2 = client.generate(&req)?;
        assert_eq!(r1.tokens, r2.tokens, "greedy serving is deterministic");
        // local greedy reference over the same sealed weights
        let mut st = DecodeState::new(&local, local.cfg.ctx);
        for &t in &prompt[..prompt.len() - 1] {
            decode_step(&local, &mut st, t);
        }
        let mut want = Vec::new();
        let mut last = *prompt.last().unwrap();
        for _ in 0..8 {
            let logits = decode_step(&local, &mut st, last);
            last = argmax(logits) as u16;
            want.push(last);
        }
        assert_eq!(
            r1.tokens, want,
            "served greedy tokens must match the local engine"
        );
        println!("prompt {prompt:?}: {:?}", r1.tokens);
    }

    println!("QUANT-SMOKE OK");
    srv.shutdown();
    Ok(())
}
