//! Typed-client walkthrough + end-to-end serve smoke (`make
//! serve-smoke`): start a registry server on random-weights models (no
//! artifacts needed), then drive greedy, seeded-sampled and streaming
//! requests through `serve::client::Client` over real TCP —
//! asserting the protocol v1 contract as it goes:
//!
//!   * per-request `"model"` routing: two registered variants, two
//!     genuinely different replies;
//!   * seeded sampling reproducibility: same seed → same tokens;
//!   * streaming framing: token events mirror the final summary;
//!   * stop conditions: `stop_tokens` ends with `finish_reason:stop`;
//!   * v0 compatibility: an untouched greedy request gets a v0 reply.
//!
//!     cargo run --release --example serve_client

use mosaic::model::weights::testutil::random_model_sized;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::{ModelRegistry, SamplingParams, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    // a small model family: one dense random checkpoint and its
    // 70 %-magnitude-pruned variant sealed into f16/CSR storage — the
    // Mosaic story (one checkpoint, several deployable variants) in
    // miniature
    let dense = random_model_sized(17, 3, 64, 4, 176, 96, 64);
    let mut sealed = dense.clone();
    for l in sealed.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    sealed.compact();
    println!(
        "dense {} KB, sealed variant {} KB resident",
        dense.resident_bytes() / 1024,
        sealed.resident_bytes() / 1024
    );

    let mut reg = ModelRegistry::new();
    reg.register("dense", dense)?;
    reg.register("mosaic70", sealed)?;
    let srv = Server::start_registry(
        reg,
        ServeConfig { max_batch: 4, ..Default::default() },
        0,
    )?;
    println!("registry server on {} (dense, mosaic70)", srv.addr);
    let mut client = Client::connect(srv.addr)?;
    let prompt = [1u16, 9, 4, 7];

    // ---- 1. greedy + per-request routing: same prompts, two models —
    // the variants must genuinely answer differently somewhere
    let mut any_differ = false;
    let mut a = None;
    for p0 in [1u16, 11, 23, 40] {
        let p = [p0, 9, 4, 7];
        let ra = client
            .generate(&GenRequest::greedy(&p).max_new(12).model("dense"))?;
        let rb = client.generate(
            &GenRequest::greedy(&p).max_new(12).model("mosaic70"),
        )?;
        assert_eq!(ra.model.as_deref(), Some("dense"));
        assert_eq!(rb.model.as_deref(), Some("mosaic70"));
        println!(
            "prompt {p:?}: dense -> {:?} | mosaic70 -> {:?}",
            ra.tokens, rb.tokens
        );
        any_differ |= ra.tokens != rb.tokens;
        a.get_or_insert(ra);
    }
    let a = a.unwrap();
    assert!(
        any_differ,
        "two different variants must reply differently on some prompt"
    );

    // ---- 2. seeded sampling: bit-reproducible per request
    let sp = SamplingParams {
        temperature: 0.9,
        top_k: 16,
        top_p: 0.95,
        seed: 42,
    };
    let s1 = client.generate(
        &GenRequest::greedy(&prompt).max_new(12).model("dense").sampled(sp),
    )?;
    let s2 = client.generate(
        &GenRequest::greedy(&prompt).max_new(12).model("dense").sampled(sp),
    )?;
    println!("sampled seed=42 -> {:?}", s1.tokens);
    assert_eq!(s1.tokens, s2.tokens, "same seed, same tokens");

    // ---- 3. streaming: token events arrive before the summary and
    // must mirror it (Client validates framing; we count the events)
    let mut streamed = Vec::new();
    let r = client.generate_with(
        &GenRequest::greedy(&prompt).max_new(8).model("dense").streaming(),
        |i, t| streamed.push((i, t)),
    )?;
    println!("streamed {} events -> {:?}", streamed.len(), r.tokens);
    assert_eq!(streamed.len(), r.tokens.len());
    assert!(
        r.finish_reason.is_some(),
        "streamed replies are v1 and must carry a finish_reason"
    );

    // ---- 4. stop conditions: stopping on the first greedy token
    // yields exactly one token and finish_reason "stop"
    let stop_tok = a.tokens[0];
    let stopped = client.generate(
        &GenRequest::greedy(&prompt)
            .max_new(12)
            .model("dense")
            .stop_tokens(&[stop_tok]),
    )?;
    assert_eq!(stopped.tokens, vec![stop_tok]);
    assert_eq!(stopped.finish_reason.as_deref(), Some("stop"));

    // ---- 5. v0 compatibility through the same server: an untouched
    // request serializes as v0 and the reply carries no v1 fields
    let v0 = client.generate(&GenRequest::greedy(&prompt).max_new(4))?;
    assert!(v0.finish_reason.is_none() && v0.model.is_none());
    assert!(!v0.tokens.is_empty());

    println!("SERVE-SMOKE OK");
    srv.shutdown();
    Ok(())
}
