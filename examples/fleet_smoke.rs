//! Fleet-serving smoke (`make fleet-smoke`): a dense checkpoint next
//! to its sealed 70 %-pruned variant registered **cold** from a
//! `.mosaic` artifact — no resident weights until the first routed
//! request — behind a weighted canary route, driven over real TCP
//! through the typed client. Asserts the contract the fleet layer
//! ships on:
//!
//!   * the cold entry spawns on first use and serves the same greedy
//!     bytes as an always-hot server over the same weights;
//!   * routed requests carry the logical route name on the wire and
//!     land on real backends per the seeded split;
//!   * one full idle-unload → re-wake cycle preserves output
//!     bit-identity, and the lifecycle gauges return to zero while
//!     the entry is parked Cold;
//!   * per-backend `route_stats` tallies equal the observed split.
//!
//!     cargo run --release --example fleet_smoke

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use mosaic::model::weights::testutil::random_model_sized;
use mosaic::prune::unstructured::{mask_lowest, scores, Metric};
use mosaic::serve::client::{Client, GenRequest};
use mosaic::serve::lifecycle::LifecycleState;
use mosaic::serve::router::parse_route;
use mosaic::serve::{ModelRegistry, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let dense = random_model_sized(23, 3, 64, 4, 176, 96, 64);
    let mut sealed = dense.clone();
    for l in sealed.layers.iter_mut() {
        for s in l.projs.iter_mut() {
            let t = s.dense_mut();
            let sc = scores(t, None, Metric::Magnitude);
            mask_lowest(t, &sc, 0.7);
        }
    }
    sealed.compact();
    let path = std::env::temp_dir().join("fleet_smoke_s70.mosaic");
    let bytes = mosaic::deploy::export_model(&sealed, &path)?;
    println!(
        "sealed artifact: {} KB on disk, 0 KB resident until first use",
        bytes / 1024
    );

    let mut reg = ModelRegistry::new();
    reg.register("dense", dense)?;
    reg.register_cold("mosaic70", &path)?;
    let srv = Server::start_registry(
        reg,
        ServeConfig {
            max_batch: 4,
            default_model: Some("dense".into()),
            routes: vec![parse_route("chat=dense:70,mosaic70:30")?],
            route_seed: 42,
            idle_ms: Some(200),
            ..Default::default()
        },
        0,
    )?;
    println!(
        "fleet server on {} (dense hot, mosaic70 cold, chat=70/30)",
        srv.addr
    );
    let mut client = Client::connect(srv.addr)?;
    let prompt = [1u16, 9, 4, 7];

    // ---- 1. cold spawn: the sealed entry wakes on its first request
    assert_eq!(
        srv.engine_lifecycle("mosaic70"),
        Some(LifecycleState::Cold),
        "sealed entry must register cold"
    );
    let first = client.generate(
        &GenRequest::greedy(&prompt).max_new(12).model("mosaic70"),
    )?;
    assert_eq!(srv.engine_lifecycle("mosaic70"), Some(LifecycleState::Hot));
    println!(
        "cold wake served {:?} (wake latency in queue_ms: {:.1} ms)",
        first.tokens, first.queue_ms
    );

    // ---- 2. weighted canary routing: logical name on the wire,
    // traffic split across real backends
    let mut split = [0usize; 2];
    for i in 0..40u16 {
        let r = client.generate(
            &GenRequest::greedy(&[1 + (i % 7), 9, 4]).max_new(6).model("chat"),
        )?;
        assert_eq!(r.route.as_deref(), Some("chat"));
        match r.model.as_deref() {
            Some("dense") => split[0] += 1,
            Some("mosaic70") => split[1] += 1,
            other => anyhow::bail!("routed to unknown backend {other:?}"),
        }
    }
    println!("40 routed requests: dense {} / mosaic70 {}", split[0], split[1]);
    assert!(split[0] > 0 && split[1] > 0, "both backends must take traffic");
    let stats: Vec<(String, u64)> = srv
        .route_stats("chat")
        .iter()
        .map(|(n, s)| (n.clone(), s.accepted.load(Ordering::Relaxed)))
        .collect();
    println!("route_stats accepted: {stats:?}");

    // ---- 3. idle-unload → re-wake: weights drop, gauges zero, and
    // the second life serves identical bytes
    let deadline = Instant::now() + Duration::from_secs(30);
    while srv.engine_lifecycle("mosaic70") != Some(LifecycleState::Cold) {
        anyhow::ensure!(
            Instant::now() < deadline,
            "idle reaper never re-parked the sealed entry"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let s = srv.model_stats("mosaic70").expect("stats");
    assert_eq!(s.kv_pages_in_use.load(Ordering::Relaxed), 0);
    assert_eq!(s.inflight.load(Ordering::Relaxed), 0);
    println!("idle reaper unloaded mosaic70 (kv + inflight gauges at 0)");
    let again = client.generate(
        &GenRequest::greedy(&prompt).max_new(12).model("mosaic70"),
    )?;
    assert_eq!(
        again.tokens, first.tokens,
        "re-wake must serve byte-identical greedy output"
    );
    println!("re-wake served identical bytes");

    println!("FLEET-SMOKE OK");
    srv.shutdown();
    let _ = std::fs::remove_file(&path);
    Ok(())
}
