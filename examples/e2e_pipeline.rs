//! End-to-end validation driver (ARCHITECTURE.md §E2E): exercises every
//! layer of the stack on a real workload and prints the paper's headline
//! comparisons —
//!   * RC: PJRT profile graph + Pallas weight-metric kernel (L1+L2)
//!   * PC: global/layer/projection × unstructured/composite/structured
//!   * quality: PPL on two held-out splits + 7-task zero-shot accuracy
//!   * LoRA recovery of the 80 % model through the AOT grad graph
//!   * deployment: measured native latency + P1–P5 simulation
//!
//!     cargo run --release --example e2e_pipeline [model]

use mosaic::coordinator::{choose_category, Mosaic};
use mosaic::eval::{measure_native, mean_accuracy, perplexity_native};
use mosaic::finetune::{self, LoraConfig};
use mosaic::platform::{self, ModelProfile, Workload};
use mosaic::prune::{Category, Uniformity};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or("tl1_7".into());
    let samples = 32;
    let mut mo = Mosaic::load(&model)?;
    let seq = mo.dense.cfg.ctx.min(64);
    let wt = mo.store.split("wikitext2s")?;
    let ptb = mo.store.split("ptbs")?;

    println!("== E2E: {} ({}) ==", model, mo.dense.cfg.proxy_for);
    let d_ppl = perplexity_native(&mo.dense, &wt, seq, 16);
    let d_acc = mean_accuracy(&mo.dense, &mo.store)?;
    println!("dense: ppl(wt2s) {d_ppl:.2}  acc {d_acc:.1}%\n");

    // --- E1/E2: uniformity sweep at 60/80 %
    println!("{:<6} {:<11} {:>10} {:>10} {:>7}", "p", "uniformity",
             "ppl-wt2s", "ppl-ptbs", "acc%");
    for p in [0.6, 0.8] {
        for u in [Uniformity::Global, Uniformity::Layer,
                  Uniformity::Projection] {
            let m = mo.prune_wanda(p, u, samples)?;
            let a = perplexity_native(&m, &wt, seq, 16);
            let b = perplexity_native(&m, &ptb, seq, 16);
            let acc = mean_accuracy(&m, &mo.store)?;
            println!("{:<6} {:<11} {:>10.2} {:>10.2} {:>7.1}",
                     p, u.name(), a, b, acc);
        }
    }

    // --- E3: category sweep at 80 % (projection uniformity)
    println!("\n{:<13} {:>10} {:>9} {:>10} {:>8}", "category",
             "ppl-wt2s", "latency", "bytes", "sparsity");
    for c in [Category::Unstructured, Category::Composite,
              Category::Structured] {
        let (m, _) = mo.prune(0.8, Uniformity::Projection, c, samples)?;
        let ppl = perplexity_native(&m, &wt, seq, 16);
        let perf = measure_native(&m, 32, 8, 3);
        println!(
            "{:<13} {:>10.2} {:>8.4}s {:>10} {:>8.2}",
            c.name(), ppl, perf.latency_s, m.model_bytes(),
            mosaic::prune::unstructured::projection_sparsity(&m)
        );
    }

    // --- E4: LoRA recovery of the 80 % projection-pruned model
    println!("\n== LoRA recovery (80% projection-pruned) ==");
    let (pruned, _) =
        mo.prune(0.8, Uniformity::Projection, Category::Unstructured,
                 samples)?;
    let before_ppl = perplexity_native(&pruned, &wt, seq, 16);
    let (rows, n_rows, s) = mo.finetune_rows()?;
    let cfg = LoraConfig { steps: 60, ..Default::default() };
    let rt = mo.runtime()?;
    rt.set_weights(&pruned)?;
    let res = finetune::train_lora(rt, &rows, n_rows, s, &cfg)?;
    let mut merged = pruned.clone();
    finetune::merge_lora(&mut merged, &res.lora, cfg.rank, cfg.alpha);
    let after_ppl = perplexity_native(&merged, &wt, seq, 16);
    println!(
        "train loss {:.3} -> {:.3} in {:.1}s; ppl {before_ppl:.1} -> \
         {after_ppl:.1}",
        res.train_curve.first().unwrap().1,
        res.train_curve.last().unwrap().1,
        res.wall_s
    );

    // --- E5/deployment: category per platform + simulated perf
    println!("\n== deployment (p=0.6) ==");
    for pf in platform::testbed() {
        let cat = choose_category(&pf);
        let (m, _) = mo.prune(0.6, Uniformity::Projection, cat, samples)?;
        let prof = ModelProfile::from_weights(&m);
        let w = if pf.name == "P5" { Workload::edge() }
                else { Workload::mlperf() };
        let sim = platform::simulate(&pf, &prof, &w);
        println!(
            "{}: {:<12} sim latency {:>8.3}s  mem {:>6} MB  offload={}",
            pf.name, cat.name(), sim.latency_s, sim.mem_bytes >> 20,
            sim.offloading
        );
    }
    println!("\nmetrics:\n{}", mo.metrics.report());
    Ok(())
}
