//! Serving scenario: deploy a dense model and a 60 % composite-pruned
//! Mosaic SLM behind the continuous-batching server and replay the same
//! Poisson request trace against both — the deployment-side payoff of
//! composite pruning (more tokens/s, lower tail latency).
//!
//!     cargo run --release --example serve_demo

use std::time::{Duration, Instant};

use mosaic::coordinator::Mosaic;
use mosaic::data::trace::{generate, percentiles, Arrival, TraceConfig};
use mosaic::prune::{Category, Uniformity};
use mosaic::serve::{wait_reply, ServeConfig, Server};

fn drive(server: &Server, trace: &[mosaic::data::trace::TraceItem])
         -> (f64, f64, f64, f64) {
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut latencies = Vec::new();
    for item in trace {
        // open-loop: wait until the item's arrival time
        let target = Duration::from_secs_f64(item.at_s);
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        let sent = Instant::now();
        match server.submit(item.prompt.clone(), item.max_new) {
            Ok(rx) => pending.push((sent, rx)),
            Err(_) => {} // rejected by backpressure — counted in stats
        }
    }
    let mut tokens = 0usize;
    for (sent, rx) in pending {
        if let Ok(reply) = wait_reply(&rx, Duration::from_secs(60)) {
            latencies.push(sent.elapsed().as_secs_f64() * 1e3);
            tokens += reply.tokens.len();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (p50, p95, _p99) = percentiles(latencies);
    (tokens as f64 / wall, p50, p95, wall)
}

fn main() -> anyhow::Result<()> {
    let mut mo = Mosaic::load("tl1_7")?;
    let (pruned, _) =
        mo.prune(0.6, Uniformity::Projection, Category::Composite, 16)?;
    let trace = generate(&TraceConfig {
        arrival: Arrival::Batch, // closed-loop: saturate the engine
        rate: 200.0,
        n_requests: 48,
        prompt_len_mean: 12,
        prompt_len_max: 24,
        max_new: 8,
        ..Default::default()
    });
    println!(
        "{:<16} {:>10} {:>9} {:>9} {:>10}",
        "model", "tok/s", "p50-ms", "p95-ms", "occupancy"
    );
    for (name, model) in
        [("dense", mo.dense.clone()), ("mosaic-60%", pruned)]
    {
        let srv = Server::start(
            model,
            ServeConfig { max_batch: 6, ..Default::default() },
            0,
        )?;
        let (tps, p50, p95, _wall) = drive(&srv, &trace);
        println!(
            "{name:<16} {tps:>10.0} {p50:>9.2} {p95:>9.2} {:>10.2}",
            srv.stats.mean_occupancy()
        );
        srv.shutdown();
    }
    Ok(())
}
