//! Edge deployment scenario (the paper's P5 story): the dense model
//! cannot run on a Raspberry-Pi-class device; Mosaic finds the smallest
//! pruning level whose SLM fits, prunes with the platform-appropriate
//! category, and reports the latency cliff (Fig. 9, P3/P5 panels).
//!
//!     cargo run --release --example edge_deploy

use mosaic::coordinator::{choose_category, Mosaic};
use mosaic::eval::perplexity_native;
use mosaic::platform::{self, memory_required, ModelProfile, Workload};
use mosaic::prune::Uniformity;

fn main() -> anyhow::Result<()> {
    let mut mo = Mosaic::load("tl1_7")?;
    let pf = platform::by_name("P5").unwrap();
    let w = Workload::edge();
    println!("target: {} — {}", pf.name, pf.description);

    // Scale the tiny model's byte footprint up to paper scale so the
    // capacity arithmetic matches Fig. 9 (LLaMa-7B on a 4 GB device).
    let scale = 6.74e9 * 2.0 / mo.dense.model_bytes() as f64;

    let dense_prof = {
        let mut p = ModelProfile::from_weights(&mo.dense);
        p.bytes = (p.bytes as f64 * scale) as u64;
        p.d_model = 4096;
        p.n_heads = 32;
        p.n_layers = 32;
        p
    };
    let need = memory_required(&dense_prof, &w) + pf.lib_overhead;
    println!(
        "dense needs {} MB vs {} MB capacity -> {}",
        need >> 20,
        pf.mem_bytes >> 20,
        if need > pf.mem_bytes { "DOES NOT FIT (paper: cannot run)" }
        else { "fits" }
    );

    // Find the smallest p (in 10 % steps) whose pruned model fits.
    let cat = choose_category(&pf);
    println!("platform category: {}", cat.name());
    let wt = mo.store.split("wikitext2s")?;
    let seq = mo.dense.cfg.ctx.min(64);
    for step in 1..=9 {
        let p = step as f64 / 10.0;
        let (m, _) = mo.prune(p, Uniformity::Projection, cat, 16)?;
        let mut prof = ModelProfile::from_weights(&m);
        prof.bytes = (prof.bytes as f64 * scale) as u64;
        prof.d_model = (4096.0
            * m.layers[0].kept_heads.len() as f64
            / m.cfg.n_heads as f64) as usize;
        prof.n_layers = 32;
        prof.n_heads = 32 * m.layers[0].kept_heads.len() / m.cfg.n_heads;
        let sim = platform::simulate(&pf, &prof, &w);
        let ppl = perplexity_native(&m, &wt, seq, 8);
        println!(
            "p={p:.1}: {} MB, sim latency {:>8.2}s, fits={} ppl={ppl:.1}",
            (memory_required(&prof, &w) + pf.lib_overhead) >> 20,
            sim.latency_s,
            sim.fits,
        );
        if sim.fits {
            println!("=> deploying the p={p:.1} {} SLM to {}",
                     cat.name(), pf.name);
            break;
        }
    }
    Ok(())
}
